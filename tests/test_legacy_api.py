"""Deprecation shims (ISSUE 5 satellite): every legacy entry point
survives as a documented shim over the channel/RunSpec API — one
``DeprecationWarning`` each, bit-identical behavior.

The heavyweight bitwise parity matrix lives in
``tests/test_channel_parity.py``; this file pins the *shim contract*:
the warning fires exactly at the legacy surface, the non-deprecated
replacement is silent, and the two produce the same objects/states.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core.api import get_compressor, make_compressor
from repro.optim import get_optimizer
from repro.run import RunSpec, build_run
from repro.run.build import lr_schedule
from repro.run.presets import build_preset

from test_channel_parity import assert_trees_equal, tiny_setup

BATCH, SEQ = 4, 16


def _no_deprecation(record) -> None:
    deps = [w for w in record if issubclass(w.category, DeprecationWarning)
            and "repro" in str(w.message)]
    assert not deps, f"replacement surface warned: {deps[0].message}"


# ------------------------------------------------------------ get_compressor


class TestGetCompressorShim:
    def test_warns_once_and_matches_make_compressor(self):
        with pytest.warns(DeprecationWarning, match="make_compressor"):
            legacy = get_compressor("sbc")
        with warnings.catch_warnings(record=True) as record:
            warnings.simplefilter("always")
            new = make_compressor("sbc")
        _no_deprecation(record)
        assert legacy.name == new.name
        assert legacy.policy == new.policy

    def test_bit_identical_compression(self, rng):
        with pytest.warns(DeprecationWarning):
            legacy = get_compressor("sbc")
        new = make_compressor("sbc")
        x = jax.random.normal(rng, (512,))
        a = legacy.compress_leaf(x, 0.05, rng)
        b = new.compress_leaf(x, 0.05, rng)
        np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
        np.testing.assert_array_equal(np.asarray(a.mean), np.asarray(b.mean))
        assert float(a.nbits) == float(b.nbits)


# -------------------------------------------------------------- DSGDTrainer


class TestDSGDTrainerShim:
    def test_warns_and_matches_runspec(self):
        from repro.data import client_batches
        from repro.train import DSGDTrainer

        spec = RunSpec(preset="tiny", backend="local", rounds=1,
                       batch=BATCH, seq_len=SEQ, clients=2, delay=1,
                       sparsity=0.05)
        cfg, model, task = tiny_setup()
        with pytest.warns(DeprecationWarning, match="build_run"):
            trainer = DSGDTrainer(
                model=model, compressor=make_compressor("sbc"),
                optimizer=get_optimizer(cfg.local_opt), n_clients=2,
                lr=lr_schedule(cfg.base_lr),
            )
        legacy_state, _ = trainer.fit(
            jax.random.PRNGKey(0), client_batches(task, 2, 1),
            n_rounds=1, n_delay=1, sparsity=0.05,
        )
        with warnings.catch_warnings(record=True) as record:
            warnings.simplefilter("always")
            run = build_run(spec)
        _no_deprecation(record)
        state, _ = run.run()
        assert_trees_equal(state.params, legacy_state.params, "params")
        assert_trees_equal(state.comp_state.residual,
                           legacy_state.comp_state.residual, "residuals")


# ------------------------------------------------------------ make_dist_train


class TestMakeDistTrainShim:
    def test_warns_and_matches_build_dist_train(self):
        from jax.sharding import Mesh

        from repro.launch.dist import build_dist_train, make_dist_train

        cfg, _ = build_preset("tiny", batch=BATCH, seq_len=SEQ)
        mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1),
                    ("data", "model"))
        with pytest.warns(DeprecationWarning, match="build_dist_train"):
            legacy = make_dist_train(cfg, mesh, sparsity=0.05)
        with warnings.catch_warnings(record=True) as record:
            warnings.simplefilter("always")
            new = build_dist_train(cfg, mesh, sparsity=0.05)
        _no_deprecation(record)
        assert legacy.bits_per_client == new.bits_per_client
        assert legacy.bits_dense == new.bits_dense
        assert [gl for gl in legacy.channel.leaves] == \
            [gl for gl in new.channel.leaves]
