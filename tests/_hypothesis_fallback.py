"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

``hypothesis`` is an *optional* dev dependency (see requirements-dev.txt):
when present, property tests explore the full strategy space; when absent,
this shim runs each ``@given`` test over a small fixed grid of example
values drawn from the same strategies, so tier-1 stays green and the
properties still get exercised on representative inputs.

Only the strategy surface this repo's tests use is implemented:
``st.integers``, ``st.sampled_from``, ``st.lists``.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:  # optional dev dep — fall back to a fixed grid
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations


import itertools


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        lo, hi = int(min_value), int(max_value)
        mid = (lo + hi) // 2
        # dedupe while preserving order (tiny ranges collapse)
        return _Strategy(dict.fromkeys([lo, mid, hi]))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        return _Strategy(elements)

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10, unique=False) -> _Strategy:
        base = elements.examples
        pool = list(dict.fromkeys(base)) if unique else list(base)
        # three shapes: smallest, a mid-sized mix, and the largest we can
        # build from the element examples (capped at max_size)
        sizes = sorted({max(min_size, 1), min(max_size, max(min_size, 3)),
                        min(max_size, len(pool))})
        out = []
        for s in sizes:
            if s == 0:
                out.append([])
                continue
            if unique:
                if len(pool) < s:
                    continue
                out.append(pool[:s])
            else:
                out.append([base[i % len(base)] for i in range(s)])
        return _Strategy(out or [[]])


def given(**strategies):
    names = sorted(strategies)
    grids = [strategies[n].examples for n in names]

    def deco(fn):
        def wrapper(*args, **kwargs):
            # cap the cartesian product so fallback runs stay fast
            for combo in itertools.islice(itertools.product(*grids), 24):
                fn(*args, **dict(zip(names, combo)), **kwargs)

        # NOT functools.wraps: pytest must see the wrapper's bare (*args)
        # signature, or it would treat the strategy params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(**_kwargs):
    def deco(fn):
        return fn

    return deco
