"""Channel/Run-layer overhead gate (ISSUE 5 satellite).

The §12 redesign routes every round through ``CommChannel.round_exchange``
and the ``Run.step`` driver.  The channel call is INSIDE the jit (zero
graph cost by construction — the parity matrix pins bit-identity), so the
only possible regression is host-side dispatch: rate resolution, the Run
indirection, metrics dict plumbing.  This benchmark measures it directly:

  direct   the pre-§12 drive: ``DSGDTrainer.round_step`` called in a bare
           loop with precomputed static rates (what PR 4 timed),
  run_api  the same rounds through ``build_run(spec)`` → ``Run.step``.

A third interleaved path, ``traced``, runs the same rounds with an
ENABLED ``repro.obs`` telemetry bundle attached (spans + fence +
metrics).  The instrumented-but-disabled path is ``run_api`` itself —
every ``Run.step`` already holds the ``NULL_TELEMETRY`` no-ops — so the
telemetry layer's zero-overhead-by-default claim is gated as
``telemetry_disabled_overhead_frac < 0.01`` (run_api vs direct), while
the enabled cost is reported informationally (its per-round fence is a
deliberate ``block_until_ready``).

All paths run the SAME compiled computation (one warm-up round each),
sampled in interleaved round-robin so CI-runner drift hits each equally;
we report per-round medians and gate ``overhead_frac < 0.05`` plus the
telemetry bound in ``benchmarks/check_regression.py``.

  PYTHONPATH=src python -m benchmarks.run_api_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax

from benchmarks.common import save_json
from repro.run import RunSpec, build_run

PRESET = "lenet5"
ROUNDS_TIMED = 30
BOUND = 0.05  # the <5% acceptance bound
TELEMETRY_BOUND = 0.01  # disabled telemetry must stay under 1%


def _spec(rounds: int) -> RunSpec:
    return RunSpec(
        preset=PRESET,
        backend="local",
        rounds=rounds,
        batch=16,
        clients=4,
        delay=1,
        sparsity=0.01,
    )


def bench(timed_rounds: int = ROUNDS_TIMED) -> dict:
    spec = _spec(timed_rounds)
    run = build_run(spec)
    # build_run attaches an enabled make_telemetry() when the spec asks
    run_traced = build_run(spec.replace(telemetry=True))
    assert run_traced.telemetry.enabled
    trainer, batch_fn = run.trainer, run.batch_fn

    # independent states so no path aliases another's buffers
    state_direct = trainer.init(jax.random.PRNGKey(0))
    state_run = trainer.init(jax.random.PRNGKey(0))
    state_traced = run_traced.trainer.init(jax.random.PRNGKey(0))
    rates = trainer.resolved(state_direct.params).rates(spec.sparsity, 0)

    def step_direct(state, r):
        state, m = trainer.round_step(
            state, batch_fn(r), n_delay=spec.delay, sparsity=rates
        )
        return state, m

    def step_run(state, r):
        return run.step(state, r)

    def step_traced(state, r):
        return run_traced.step(state, r)

    # warm-up: one compile each (identical jit cache key → rest are hits)
    state_direct, _ = step_direct(state_direct, 0)
    state_run, _ = step_run(state_run, 0)
    state_traced, _ = step_traced(state_traced, 0)

    def timed(fn, state, r, sink):
        t0 = time.perf_counter()
        state, m = fn(state, r)
        jax.block_until_ready(m["loss"])
        sink.append(1e3 * (time.perf_counter() - t0))
        return state

    paths = [
        (step_direct, state_direct, direct_ms := []),
        (step_run, state_run, run_ms := []),
        (step_traced, state_traced, traced_ms := []),
    ]
    for r in range(1, timed_rounds + 1):
        # rotate which path goes first so runner drift and cache warmth
        # bias none of them
        for i in range(len(paths)):
            fn, state, sink = paths[(r + i) % len(paths)]
            paths[(r + i) % len(paths)] = (fn, timed(fn, state, r, sink), sink)

    direct = statistics.median(direct_ms)
    run_api = statistics.median(run_ms)
    traced = statistics.median(traced_ms)
    overhead = (run_api - direct) / direct
    return {
        "preset": PRESET,
        "n_clients": spec.clients,
        "timed_rounds": timed_rounds,
        "direct_step_ms": direct,
        "run_api_step_ms": run_api,
        "traced_step_ms": traced,
        "overhead_frac": overhead,
        "overhead_within_bound": bool(overhead < BOUND),
        "bound": BOUND,
        # run_api IS the instrumented-with-no-ops path: its delta over the
        # bare loop bounds what disabled telemetry costs per round
        "telemetry_disabled_overhead_frac": overhead,
        "telemetry_disabled_within_bound": bool(overhead < TELEMETRY_BOUND),
        "telemetry_enabled_overhead_frac": (traced - direct) / direct,
        "telemetry_bound": TELEMETRY_BOUND,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true", help="fewer timed rounds (what CI runs)"
    )
    args = ap.parse_args(argv)
    rec = bench(timed_rounds=16 if args.smoke else ROUNDS_TIMED)
    path = save_json("run_api_overhead", rec)
    print(
        f"run_api_overhead: direct {rec['direct_step_ms']:.2f} ms/round, "
        f"run-api {rec['run_api_step_ms']:.2f} ms/round "
        f"({100 * rec['overhead_frac']:+.1f}%, bound {100 * BOUND:.0f}%; "
        f"telemetry bound {100 * TELEMETRY_BOUND:.0f}%), "
        f"traced {rec['traced_step_ms']:.2f} ms/round "
        f"({100 * rec['telemetry_enabled_overhead_frac']:+.1f}%) "
        f"→ {path}"
    )
    return rec


def run(quick: bool = True) -> dict:
    """benchmarks.run harness hook."""
    return main(["--smoke"] if quick else [])


if __name__ == "__main__":
    main()
