"""Paper Fig. 5/6/7/8 — convergence vs iterations AND vs transferred bits.

Produces, for each method, the (iteration, loss) curve and the cumulative
upload bits — the data behind the paper's left/right panel pairs.  The
bits axis is where SBC's 3-4 orders of magnitude show up.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import METHODS, bench_tasks, run_training, save_json


def run(quick: bool = True) -> dict:
    tag, cfg, task, n_rounds, lr = bench_tasks(quick)[2]  # transformer@markov
    n_rounds = n_rounds * 2  # longer horizon for curve shape
    out = {}
    for name, comp, delay, p in METHODS:
        if quick and delay > n_rounds // 2:
            delay = max(1, n_rounds // 4)
        hist = run_training(
            cfg,
            task,
            compressor=comp,
            n_rounds=n_rounds,
            delay=delay,
            sparsity=p,
            lr=lr,
        )
        bits = np.cumsum(hist["bits_per_client"]).tolist()
        out[name] = {
            "iterations": hist["iterations"],
            "loss": hist["loss"],
            "cumulative_bits": bits,
            "final_loss": hist["loss"][-1],
            "total_bits": bits[-1],
        }
        print(
            f"{name:>14}: final loss {hist['loss'][-1]:.4f} after "
            f"{hist['iterations'][-1] + delay} iters, {bits[-1]:.3e} bits up"
        )

    # loss-at-equal-bits comparison (the paper's right-panel reading)
    base_bits = out["baseline"]["total_bits"]
    for name, r in out.items():
        r["bits_vs_baseline"] = base_bits / max(r["total_bits"], 1.0)
    save_json("fig5_convergence", out)
    return out


if __name__ == "__main__":
    run()
