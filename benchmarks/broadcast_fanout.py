"""Delta-broadcast fan-out: bytes/subscriber/round at 10k+ subscribers.

Drives the production broadcast path (``ParameterServer`` with a
``DeltaLog`` attached — DESIGN.md §13) on the fed-micro transformer and
fans every round out to a :class:`~repro.serve.broadcast.SubscriberPool`
with heterogeneous sync periods, so the planner prices real replay /
stacked / full catch-ups for every lag class.

The byte fields are deterministic (threefry updates, fixed seed), so the
committed JSON doubles as a cross-machine regression baseline
(``benchmarks/check_regression.py``); only the rounds/sec fields vary.
``--smoke`` runs the IDENTICAL configuration — the whole benchmark is
CI-sized (one encode per round is the point) — and exists so the CI
invocation matches the other benchmarks' calling convention.

  PYTHONPATH=src python -m benchmarks.broadcast_fanout
  PYTHONPATH=src python -m benchmarks.broadcast_fanout --smoke

Acceptance gates (raise on violation):
  * every lag k <= horizon: chosen plan strictly cheaper than full resync
  * stacked application bit-identical to sequential replay (live-verified)
  * the SubscriberPool's BandwidthLedger reconciles (Eq. 1/Eq. 5)
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import save_json, save_telemetry
from benchmarks.fed_round import _setup
from repro.obs import make_telemetry, render_table
from repro.serve.broadcast import simulate_fanout

N_SUBSCRIBERS = 10_000
ROUNDS = 16
HORIZON = 8
DOWN_SPARSITY = 0.02
PERIODS = (1, 2, 4, 8)


def run(quick: bool = True, smoke: bool = False) -> dict:
    # quick/smoke accepted for harness uniformity; the configuration is
    # identical in every mode (see docstring)
    _, model, _, policy = _setup()
    params = model.init(jax.random.PRNGKey(0))
    telemetry = make_telemetry()
    out = simulate_fanout(
        params,
        n_subscribers=N_SUBSCRIBERS,
        rounds=ROUNDS,
        horizon=HORIZON,
        down_sparsity=DOWN_SPARSITY,
        periods=PERIODS,
        seed=0,
        verify_classes=3,
        policy=policy,
        telemetry=telemetry,
    )
    print(
        f"{out['n_subscribers']} subscribers x {out['timed_rounds']} rounds "
        f"(horizon {out['horizon']}, p_down={out['down_sparsity']}, "
        f"{out['n_params']} params)"
    )
    print(
        f"  {out['bytes_per_subscriber_per_round']:8.1f} B/subscriber/round "
        f"(full resync would be {out['full_resync_bytes']} B)"
    )
    print(
        f"  {out['bytes_saving_vs_full_resync']:8.1f}x saving vs "
        f"full-resync-every-sync"
    )
    print(
        f"  {out['rounds_per_sec']:8.2f} rounds/s  "
        f"{out['subscriber_syncs_per_sec']:8.0f} subscriber syncs/s"
    )
    print(
        render_table(
            ["lag", "plan", "bytes", "candidates"],
            [
                (
                    lag,
                    rec["kind"],
                    rec["nbytes"],
                    "  ".join(f"{k}={v}" for k, v in rec["candidates"].items()),
                )
                for lag, rec in sorted(
                    out["plan_by_lag"].items(), key=lambda kv: int(kv[0])
                )
            ],
            title="catch-up plan by lag class",
        )
    )
    if not out["catchup_beats_full_all_lags"]:
        raise AssertionError("a lag <= horizon chose a plan >= full resync cost")
    if not out["stack_bit_exact"]:
        raise AssertionError("catch-up application diverged from the replica")
    path = save_json("broadcast_fanout", out)
    print(f"wrote {path}")
    save_telemetry(
        "broadcast_fanout",
        telemetry,
        meta={
            "benchmark": "broadcast_fanout",
            "n_subscribers": N_SUBSCRIBERS,
            "rounds": ROUNDS,
        },
    )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI run (identical configuration; see docstring)",
    )
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
