"""Chaos smoke: the fed-tiny CLI run under a seeded fault schedule.

Drives ``repro.launch.fed`` (the full ``RunSpec`` → ``build_run`` →
``RoundScheduler`` stack, NOT a hand-assembled federation) three times:

  A. faulted + killed: dropouts, a straggler past ``--straggler-timeout``,
     a corrupt upload, and a mid-round ``kill_server`` — the launcher
     checkpoints, rebuilds from scratch, restores, and resumes;
  B. the same faults with the kill removed, never interrupted;
  C. failure-free.

and then holds the ISSUE 8 CI contract:

  * A's post-resume trajectory lands on B's bytes: final loss and the
    ENTIRE ledger total row are exactly equal (bit-identical mid-round
    resume, observed from the CLI surface);
  * every faulted round still reconciles measured-vs-analytic, with the
    aborted/rejected bytes metered in ``up_bytes_wasted`` (A > 0, C == 0);
  * chaos costs convergence only noise: A's final loss stays within a
    band of C's.

  PYTHONPATH=src python -m benchmarks.fed_chaos
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_json
from repro.fed import FaultSchedule
from repro.launch.fed import main as fed_main

ROUNDS, CLIENTS, COHORT, DELAY = 5, 8, 4, 2

# targets chosen inside the deterministic seed-0 cohorts of (8 choose 4):
# r1 ⊇ {4, 6}, r2 ∋ 3, r3 is the killed round
CHAOS = FaultSchedule(
    seed=7,
    drops=((1, 4),),
    slow=((2, 3, 100.0),),
    corrupt=((1, 6),),
    kill_server=((3, "post_aggregate"),),
)
NO_KILL = FaultSchedule(
    seed=7, drops=CHAOS.drops, slow=CHAOS.slow, corrupt=CHAOS.corrupt
)


def _run(faults: FaultSchedule | None) -> dict:
    argv = [
        "--rounds",
        str(ROUNDS),
        "--clients",
        str(CLIENTS),
        "--cohort",
        str(COHORT),
        "--delay",
        str(DELAY),
        "--sparsity",
        "0.05",
        "--log-every",
        "0",
    ]
    if faults is not None:
        argv += ["--faults", faults.to_json(), "--straggler-timeout", "10"]
    return fed_main(argv)


def run() -> dict:
    print("=== A: faulted + mid-round server kill (checkpoint/resume) ===")
    a = _run(CHAOS)
    print("=== B: same faults, never killed ===")
    b = _run(NO_KILL)
    print("=== C: failure-free ===")
    c = _run(None)

    totals_keys = (
        "rounds",
        "up_bytes",
        "down_bytes",
        "up_bytes_wasted",
        "up_bits_measured",
        "up_bits_analytic",
        "down_bits_measured",
        "down_bits_analytic",
    )
    resume_ledger_equal = all(a[k] == b[k] for k in totals_keys)
    resume_loss_bit_equal = a["loss"][-1] == b["loss"][-1]
    loss_parity = abs(a["loss"][-1] - c["loss"][-1]) <= 0.5 * abs(c["loss"][-1])

    out = {
        "rounds": ROUNDS,
        "clients": CLIENTS,
        "cohort": COHORT,
        "final_loss_chaos": float(a["loss"][-1]),
        "final_loss_failure_free": float(c["loss"][-1]),
        "up_bytes_wasted": int(a["up_bytes_wasted"]),
        "resume_loss_bit_equal": bool(resume_loss_bit_equal),
        "resume_ledger_equal": bool(resume_ledger_equal),
        "loss_parity_vs_failure_free": bool(loss_parity),
        "wasted_bytes_metered": bool(
            a["up_bytes_wasted"] > 0 and c["up_bytes_wasted"] == 0
        ),
        "ledger_reconciles": True,  # each run reconciled or raised
    }
    print(
        f"chaos loss {out['final_loss_chaos']:.4f} vs failure-free "
        f"{out['final_loss_failure_free']:.4f}; resume bit-equal: "
        f"loss={resume_loss_bit_equal} ledger={resume_ledger_equal}; "
        f"wasted {out['up_bytes_wasted']} B"
    )
    path = save_json("fed_chaos", out)
    print(f"wrote {path}")
    for flag in (
        "resume_loss_bit_equal",
        "resume_ledger_equal",
        "loss_parity_vs_failure_free",
        "wasted_bytes_metered",
    ):
        if not out[flag]:
            raise AssertionError(f"fed_chaos acceptance failed: {flag}")
    return out


def main(argv=None):
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args(argv)
    run()


if __name__ == "__main__":
    main()
