"""Whole-pytree compress+pack throughput: flat fast path vs per-leaf path.

Measures one communication round's codec work — residual-accumulate, SBC
selection, ΔW*/residual update, SBW1 pack — over a full model parameter
set, three ways:

  per-leaf eager   ``ResolvedPolicy.compress`` exactly as the parameter
                   server's per-round ``broadcast()`` re-compression runs
                   it today: one Python dispatch per jnp op per leaf.
                   This is the baseline the flat fast path replaces.
  per-leaf jit     the same per-leaf loop traced into one XLA call (the
                   trainer's in-graph surface) — reported for context.
  flat fast        ``fast=True`` policy → ``FlatParamSpace.compress``
                   (core/flat.py §10): flatten once, one cached jitted
                   call, single fused scatter + flat residual update.

All three must produce byte-identical SBW1 buffers (asserted here; the
bit-level equivalence matrix lives in tests/test_flat_fast_path.py).

  PYTHONPATH=src python -m benchmarks.compress_e2e            # quick
  PYTHONPATH=src python -m benchmarks.run --only compress_e2e
"""
from __future__ import annotations

import statistics
import time

import jax

from benchmarks.common import save_json
from repro.configs.base import get_config
from repro.core.api import make_compressor
from repro.core.policy import (
    DENSE_SMALL_PATTERN,
    CompressionPolicy,
    PolicyRule,
)
from repro.core.wire import wire_for
from repro.models.model import build_model

SPARSITY = 0.01


def _policy(fast: bool) -> CompressionPolicy:
    comp = make_compressor("sbc")
    return CompressionPolicy(
        default=comp.codec,
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        name="sbc+dense-small",
        fast=fast,
    )


def _time_interleaved(fns: dict, repeats: int) -> dict:
    """Median seconds per call, trials interleaved across the candidate
    paths so ambient load (this is often a busy CI box) hits all of them
    alike instead of biasing whichever ran last."""
    samples = {name: [] for name in fns}
    for name, fn in fns.items():
        fn()  # warm-up (compile + caches)
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            samples[name].append(time.perf_counter() - t0)
    return {name: statistics.median(v) for name, v in samples.items()}


def bench_arch(arch: str, repeats: int) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    delta = jax.tree.map(
        lambda x: 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape), params
    )
    n_params = sum(x.size for x in jax.tree.leaves(params))

    res_slow = _policy(fast=False).resolve(params)
    res_fast = _policy(fast=True).resolve(params)
    rates = res_slow.rates(SPARSITY, 0)
    wire = wire_for(res_slow, params, SPARSITY)

    state_slow = res_slow.init_state(params)
    state_fast = res_fast.init_state(params)
    jit_compress = jax.jit(lambda d, s: res_slow.compress(d, s, rates))

    def run_eager():
        ctree, _, _ = res_slow.compress(delta, state_slow, rates)
        return wire.pack(jax.device_get(ctree))

    def run_jit():
        ctree, _, _ = jit_compress(delta, state_slow)
        return wire.pack(jax.device_get(ctree))

    def run_fast():
        ctree, _, _ = res_fast.compress(delta, state_fast, rates)
        return wire.pack(jax.device_get(ctree))

    # correctness anchor: all three paths emit the SAME bytes
    blob_eager, blob_jit, blob_fast = run_eager(), run_jit(), run_fast()
    assert blob_eager == blob_jit == blob_fast, "paths disagree on SBW1 bytes"

    t = _time_interleaved(
        {"eager": run_eager, "jit": run_jit, "fast": run_fast}, repeats
    )
    t_eager, t_jit, t_fast = t["eager"], t["jit"], t["fast"]
    dense_mb = 4.0 * n_params / 1e6
    return {
        "arch": arch,
        "n_params": n_params,
        "n_leaves": len(jax.tree.leaves(params)),
        "sparsity": SPARSITY,
        "packed_bytes": len(blob_fast),
        "per_leaf_eager_ms": 1e3 * t_eager,
        "per_leaf_jit_ms": 1e3 * t_jit,
        "flat_fast_ms": 1e3 * t_fast,
        "flat_fast_dense_mb_s": dense_mb / t_fast,
        "speedup_vs_per_leaf": t_eager / t_fast,
        "speedup_vs_per_leaf_jit": t_jit / t_fast,
    }


def run(quick: bool = True) -> None:
    archs = ["resnet32", "charlstm"]
    repeats = 8 if quick else 25
    rows = [bench_arch(a, repeats) for a in archs]
    print(
        f"{'arch':12s} {'params':>9s} {'per-leaf ms':>12s} {'jit ms':>8s} "
        f"{'flat ms':>8s} {'x vs leaf':>10s} {'x vs jit':>9s}"
    )
    for r in rows:
        print(
            f"{r['arch']:12s} {r['n_params']:>9d} "
            f"{r['per_leaf_eager_ms']:>11.1f} {r['per_leaf_jit_ms']:>7.1f} "
            f"{r['flat_fast_ms']:>7.1f} {r['speedup_vs_per_leaf']:>9.1f}× "
            f"{r['speedup_vs_per_leaf_jit']:>8.2f}×"
        )
    path = save_json("compress_e2e", rows)
    print(f"wrote {path}")


if __name__ == "__main__":
    run(quick=True)
