"""Paper Fig. 3 / Fig. 9 — the temporal-vs-gradient sparsity trade-off grid.

Trains the small transformer at every (delay, sparsity) grid point with a
FIXED iteration budget and reports final loss.  The paper's claims checked:
  1. loss ≈ constant along iso-total-sparsity diagonals,
  2. a roughly triangular feasible region (top-right corner degrades).
"""
from __future__ import annotations

import math

from benchmarks.common import bench_tasks, run_training, save_json


def run(quick: bool = True) -> dict:
    tag, cfg, task, n_rounds, lr = bench_tasks(quick)[2]  # transformer@markov
    delays = (1, 4, 16) if quick else (1, 2, 5, 10, 25, 50)
    sparsities = (1.0, 0.1, 0.01) if quick else (1.0, 0.1, 0.01, 0.001)
    budget = n_rounds  # iterations (fwd-bwd passes), held constant

    grid = []
    for n in delays:
        for p in sparsities:
            hist = run_training(
                cfg,
                task,
                compressor="sbc" if p < 1 else "none",
                n_rounds=budget,
                delay=n,
                sparsity=p,
                lr=lr,
            )
            total_sparsity = p / n
            grid.append(
                {
                    "delay": n,
                    "sparsity": p,
                    "total_sparsity": total_sparsity,
                    "final_loss": hist["loss"][-1],
                    "compression_rate": hist["compression_rate"],
                }
            )
            print(
                f"delay={n:>3} p={p:>6}: loss {hist['loss'][-1]:.4f} "
                f"(total sparsity {total_sparsity:.1e})"
            )

    # diagonal-constancy check: group by total sparsity decade
    by_decade: dict[int, list[float]] = {}
    for g in grid:
        d = round(math.log10(g["total_sparsity"]))
        by_decade.setdefault(d, []).append(g["final_loss"])
    diag = {
        str(d): {
            "mean": sum(v) / len(v),
            "spread": max(v) - min(v),
            "n": len(v),
        }
        for d, v in by_decade.items()
        if len(v) > 1
    }
    out = {"grid": grid, "iso_diagonals": diag}
    save_json("fig3_sparsity_grid", out)
    for d, s in sorted(diag.items()):
        print(
            f"total-sparsity decade 1e{d}: mean loss {s['mean']:.3f} "
            f"spread {s['spread']:.3f} over {s['n']} points"
        )
    return out


if __name__ == "__main__":
    run()
