"""Distributed round step time: sharded flat exchange vs per-leaf shard_map.

Measures one full DSGD train_step — local steps, residual add, per-shard
SBC compression, cross-client exchange, momentum masking — on a forced
8-device host mesh ((2, 2, 2) 'pod'/'data'/'model'), two ways:

  per-leaf    the PR 2 shard_map exchange: one lax.scan of top-k per leaf
              and 2 all_gathers per leaf per client axis.
  flat        the §11 ``ShardedFlatParamSpace`` exchange: every device
              compresses its shard of ONE block-padded flat buffer, one
              fused scatter, one packed (positions, μ) all_gather per
              client axis, flat sharded residual state.

Both paths must produce bit-identical parameters (asserted here; the full
parity matrix lives in tests/dist_flat_check.py).  Because forcing host
devices needs XLA_FLAGS before jax initializes, the measurement runs in a
subprocess; ``--child`` is that entry point.

  PYTHONPATH=src python -m benchmarks.dist_flat            # quick
  PYTHONPATH=src python -m benchmarks.dist_flat --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MARK = "DIST_FLAT_JSON "
N_DEVICES = 8


def _bench_child(repeats: int) -> dict:
    """Runs under 8 forced host devices (see main): the actual timing."""
    import statistics
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.launch.dist import build_dist_train, client_topology
    from repro.models.model import build_model

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(
        name="bench", family="decoder", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, dtype=jnp.float32,
        client_mode="data", local_opt="momentum", base_lr=0.05,
        scan_layers=True,
    )
    model = build_model(cfg)
    n_clients, _ = client_topology(cfg, mesh)
    sparsity = 0.01
    per_leaf = build_dist_train(cfg, mesh, sparsity=sparsity, model=model)
    flat = build_dist_train(cfg, mesh, sparsity=sparsity, model=model, fast=True)
    assert flat.flat_space is not None

    rng = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(rng, (n_clients, 2, 64), 0, 256),
        "labels": jax.random.randint(rng, (n_clients, 2, 64), 0, 256),
    }

    states, batches = {}, {}
    for name, fns in (("per_leaf", per_leaf), ("flat", flat)):
        states[name] = jax.device_put(
            fns.init_state(jax.random.PRNGKey(0)), fns.state_shardings
        )
        batches[name] = jax.device_put(batch, fns.batch_shardings(batch))

    # correctness anchor: one step from identical inits, identical params
    # (also the compile call — the flat path lowers O(1) collectives
    # instead of O(leaves), which shows up as compile time on every mesh)
    t0 = time.perf_counter()
    s_pl, m = per_leaf.train_step(states["per_leaf"], batches["per_leaf"])
    jax.block_until_ready(m["loss"])
    compile_pl = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_fl, m = flat.train_step(states["flat"], batches["flat"])
    jax.block_until_ready(m["loss"])
    compile_fl = time.perf_counter() - t0
    parity = all(
        np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(jax.tree.leaves(s_pl["params"]),
                        jax.tree.leaves(s_fl["params"]))
    )
    states = {"per_leaf": s_pl, "flat": s_fl}

    # interleaved timing so ambient load hits both paths alike
    fns_by = {"per_leaf": per_leaf, "flat": flat}
    samples: dict = {"per_leaf": [], "flat": []}
    for _ in range(repeats):
        for name in samples:
            t0 = time.perf_counter()
            states[name], m = fns_by[name].train_step(
                states[name], batches[name]
            )
            jax.block_until_ready(m["loss"])
            samples[name].append(time.perf_counter() - t0)
    t_pl = statistics.median(samples["per_leaf"])
    t_fl = statistics.median(samples["flat"])

    n_params = sum(
        x.size for x in jax.tree.leaves(states["flat"]["params"])
    )
    return {
        "n_devices": N_DEVICES,
        "mesh": "2x2x2 pod/data/model",
        "client_mode": cfg.client_mode,
        "n_clients": n_clients,
        "n_params": n_params,
        "sparsity": sparsity,
        "repeats": repeats,
        "per_leaf_step_ms": 1e3 * t_pl,
        "flat_step_ms": 1e3 * t_fl,
        "speedup": t_pl / t_fl,
        "per_leaf_compile_s": compile_pl,
        "flat_compile_s": compile_fl,
        "compile_speedup": compile_pl / compile_fl,
        "bits_per_client": flat.bits_per_client,
        "bits_equal": per_leaf.bits_per_client == flat.bits_per_client,
        "parity": bool(parity),
    }


def run(quick: bool = True) -> dict:
    """Spawn the 8-device child, collect and persist its measurement."""
    from benchmarks.common import save_json

    repeats = 5 if quick else 15
    env = dict(os.environ)
    # forced host devices only exist on the CPU backend — pin it so the
    # child's 8-device mesh builds on GPU/TPU dev boxes too
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_flat", "--child",
         "--repeats", str(repeats)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=ROOT,
    )
    out = proc.stdout + proc.stderr
    if proc.returncode != 0:
        raise RuntimeError(f"dist_flat child failed:\n{out[-3000:]}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(MARK):
            payload = json.loads(line[len(MARK):])
    assert payload is not None, out[-3000:]
    assert payload["parity"], "flat and per-leaf params diverged"
    assert payload["bits_equal"], "Eq. 1 bit accounting diverged"
    print(
        f"{payload['n_devices']} devices, {payload['n_clients']} clients, "
        f"{payload['n_params']} params, p={payload['sparsity']}"
    )
    print(
        f"per-leaf {payload['per_leaf_step_ms']:.1f} ms/step   "
        f"flat {payload['flat_step_ms']:.1f} ms/step   "
        f"x{payload['speedup']:.2f}  (parity={payload['parity']})"
    )
    print(
        f"compile: per-leaf {payload['per_leaf_compile_s']:.1f} s   "
        f"flat {payload['flat_compile_s']:.1f} s   "
        f"x{payload['compile_speedup']:.2f}"
    )
    path = save_json("dist_flat", payload)
    print(f"wrote {path}")
    return payload


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run (default size)")
    ap.add_argument("--full", action="store_true", help="more timing repeats")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--repeats", type=int, default=5)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.child:
        payload = _bench_child(args.repeats)
        print(MARK + json.dumps(payload))
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
