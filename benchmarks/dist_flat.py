"""Distributed round step time: sharded flat exchange vs per-leaf shard_map.

Measures one full DSGD train_step — local steps, residual add, per-shard
SBC compression, cross-client exchange, momentum masking — on a forced
8-device host mesh ((2, 2, 2) 'pod'/'data'/'model'), two ways:

  per-leaf    the PR 2 shard_map exchange: one lax.scan of top-k per leaf
              and 2 all_gathers per leaf per client axis.
  flat        the §11 ``ShardedFlatParamSpace`` exchange: every device
              compresses its shard of ONE block-padded flat buffer, one
              fused scatter, one packed (positions, μ) all_gather per
              client axis, flat sharded residual state.

It then measures one WIRE ROUND — a communication round where the Golomb
bitstream is the cohort transport, end to end through to the aggregated
mean — two ways:

  per-leaf + host wire    exchange over raw index arrays, then the host
                          produces every client's transport bytes
                          (``golomb.encode_positions_packed`` per row) and
                          the server decodes every stream back to
                          positions (``golomb.decode_positions``, the
                          parameter-server hot path).
  flat + device pack      the §11 fused select→pack kernels: the exchange
                          all_gathers PACKED uint32 words (the transport
                          itself), decodes them on-device, and the wire
                          bytes are a truncating copy of the word buffer.

Both step paths must produce bit-identical parameters, and both wire
paths byte-identical streams (asserted here; the full parity matrix
lives in tests/dist_flat_check.py and tests/test_channel_parity.py).
Because forcing host devices needs XLA_FLAGS before jax initializes, the
measurement runs in a subprocess; ``--child`` is that entry point.

  PYTHONPATH=src python -m benchmarks.dist_flat            # quick
  PYTHONPATH=src python -m benchmarks.dist_flat --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MARK = "DIST_FLAT_JSON "
N_DEVICES = 8
MIN_WIRE_SPEEDUP = 1.15


def _bench_child(repeats: int) -> dict:
    """Runs under 8 forced host devices (see main): the actual timing."""
    import statistics
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import ModelConfig
    from repro.core import golomb
    from repro.core.channel import _iter_shard_blocks
    from repro.launch.dist import _lead_spec, build_dist_train, client_topology
    from repro.models.model import build_model, make_param_specs

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(
        name="bench", family="decoder", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, dtype=jnp.float32,
        client_mode="data", local_opt="momentum", base_lr=0.05,
        scan_layers=True,
    )
    model = build_model(cfg)
    n_clients, client_axes = client_topology(cfg, mesh)
    sparsity = 0.01
    per_leaf = build_dist_train(cfg, mesh, sparsity=sparsity, model=model)
    flat = build_dist_train(cfg, mesh, sparsity=sparsity, model=model, fast=True)
    packed = build_dist_train(
        cfg, mesh, sparsity=sparsity, model=model, fast=True, device_pack=True
    )
    assert flat.flat_space is not None

    rng = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(rng, (n_clients, 2, 64), 0, 256),
        "labels": jax.random.randint(rng, (n_clients, 2, 64), 0, 256),
    }

    states, batches = {}, {}
    for name, fns in (("per_leaf", per_leaf), ("flat", flat)):
        states[name] = jax.device_put(
            fns.init_state(jax.random.PRNGKey(0)), fns.state_shardings
        )
        batches[name] = jax.device_put(batch, fns.batch_shardings(batch))

    # correctness anchor: one step from identical inits, identical params
    # (also the compile call — the flat path lowers O(1) collectives
    # instead of O(leaves), which shows up as compile time on every mesh)
    t0 = time.perf_counter()
    s_pl, m = per_leaf.train_step(states["per_leaf"], batches["per_leaf"])
    jax.block_until_ready(m["loss"])
    compile_pl = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_fl, m = flat.train_step(states["flat"], batches["flat"])
    jax.block_until_ready(m["loss"])
    compile_fl = time.perf_counter() - t0
    parity = all(
        np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(
            jax.tree.leaves(s_pl["params"]), jax.tree.leaves(s_fl["params"])
        )
    )
    # device-pack path: same step from the same init must land on the
    # same parameters (the packed words ride along, they never perturb)
    s_pk, m = packed.train_step(
        jax.device_put(
            packed.init_state(jax.random.PRNGKey(0)), packed.state_shardings
        ),
        jax.device_put(batch, packed.batch_shardings(batch)),
    )
    jax.block_until_ready(m["loss"])
    pack_parity = all(
        np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(
            jax.tree.leaves(s_fl["params"]), jax.tree.leaves(s_pk["params"])
        )
    )
    states = {"per_leaf": s_pl, "flat": s_fl}

    # snapshot the 1-step residuals for the wire round now — the timing
    # loop below donates s_pl's buffers, and the wire paths must see
    # IDENTICAL residual content (one local step from the same init, where
    # parity holds) or their byte totals drift apart
    res_pl = jax.tree.map(jnp.copy, s_pl["residual"])
    res_pk = s_pk["residual"]

    # interleaved timing so ambient load hits both paths alike
    fns_by = {"per_leaf": per_leaf, "flat": flat}
    samples: dict = {"per_leaf": [], "flat": []}
    for _ in range(repeats):
        for name in samples:
            t0 = time.perf_counter()
            states[name], m = fns_by[name].train_step(
                states[name], batches[name]
            )
            jax.block_until_ready(m["loss"])
            samples[name].append(time.perf_counter() - t0)
    t_pl = statistics.median(samples["per_leaf"])
    t_fl = statistics.median(samples["flat"])

    # ---------------------------------------------------------- wire round
    # Time the exchange as a TRANSPORT round: compressed bytes in, mean
    # out, for the whole cohort.  The per-leaf path exchanges raw index
    # arrays, so the host must still produce every client's bitstream and
    # the server must decode every stream; the device-pack exchange
    # gathers the packed words themselves and decodes on-device, so its
    # wire bytes are a truncating copy.
    ch_pl, ch_pk = per_leaf.channel, packed.channel
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = make_param_specs(
        a_params, mesh, fsdp=cfg.fsdp, expert_parallel=False
    )
    flat_specs = tuple(
        jax.tree.leaves(p_specs, is_leaf=lambda s: isinstance(s, P))
    )
    lead = _lead_spec(client_axes)
    round_specs = tuple(P(lead, *s) for s in flat_specs)
    shard_axes = tuple(a for a in mesh.axis_names if a not in client_axes)
    res_spec = P(lead, _lead_spec(shard_axes), None)

    deltas = jax.tree.map(
        lambda p: 0.01 * jax.random.normal(
            jax.random.PRNGKey(2), (n_clients,) + p.shape, jnp.float32
        ),
        states["per_leaf"]["params"],
    )
    deltas = jax.device_put(
        deltas,
        jax.tree.unflatten(
            jax.tree.structure(deltas),
            [NamedSharding(mesh, s) for s in round_specs],
        ),
    )
    ex_pl = jax.jit(lambda res, d: ch_pl.round_exchange(
        res, d, mesh=mesh, in_specs=round_specs, res_spec=res_spec,
        need_own=True,
    ))
    ex_pk = jax.jit(lambda res, d: ch_pk.round_exchange(
        res, d, mesh=mesh, in_specs=round_specs, res_spec=res_spec,
        need_own=True,
    ))
    space = ch_pk.flat_space
    dense_bytes = sum(
        4 * int(np.prod(gl.global_shape) or 1)
        for gl in ch_pl.leaves if gl.mode == "dense"
    )

    def wire_round_pl() -> int:
        mean, _, own = ex_pl(res_pl, deltas)
        jax.block_until_ready(jax.tree.leaves(mean)[0])
        nbytes = n_clients * dense_bytes
        for c in range(n_clients):
            own_c = jax.tree.map(lambda o: np.asarray(o[c]), own)
            for gl, leaf in zip(ch_pl.leaves, jax.tree.leaves(own_c)):
                if gl.mode != "sparse":
                    continue
                for block in _iter_shard_blocks(np.asarray(leaf), gl.shard_grid):
                    L = block.shape[0] if gl.scanned and block.ndim > 1 else 1
                    for row in block.reshape(L, -1):
                        pos = np.flatnonzero(row)
                        blob, nb = golomb.encode_positions_packed(pos, gl.rate)
                        nbytes += len(blob) + 4  # +32-bit μ
                        bits = np.unpackbits(np.frombuffer(blob, np.uint8))[:nb]
                        golomb.decode_positions(bits, gl.rate)
        return nbytes

    def wire_round_pk() -> int:
        mean, _, own, (words, nbits) = ex_pk(res_pk, deltas)
        jax.block_until_ready(jax.tree.leaves(mean)[0])
        w_all = np.asarray(jax.device_get(words))
        nb_all = np.asarray(jax.device_get(nbits))
        n_dev = w_all.shape[1]
        nbytes = n_clients * dense_bytes
        for c in range(n_clients):
            for s_ in range(n_dev):
                mi = 0
                for seg, (_, w, off) in zip(space._sparse, space._pack_info):
                    reps = n_dev // seg.n_shards
                    for r in range(seg.rows):
                        if s_ % reps == 0:  # distinct shard replicas only
                            blob = golomb.packed_words_to_bytes(
                                w_all[c, s_, off + r * w: off + (r + 1) * w],
                                int(nb_all[c, s_, mi]),
                            )
                            nbytes += len(blob) + 4
                        mi += 1
        return nbytes

    wire_bytes_pl = wire_round_pl()  # compile + 1st
    wire_bytes_pk = wire_round_pk()
    wire_samples: dict = {"pl": [], "pk": []}
    for _ in range(repeats):
        t0 = time.perf_counter()
        wire_round_pl()
        wire_samples["pl"].append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        wire_round_pk()
        wire_samples["pk"].append(time.perf_counter() - t0)
    t_wire_pl = statistics.median(wire_samples["pl"])
    t_wire_pk = statistics.median(wire_samples["pk"])

    n_params = sum(
        x.size for x in jax.tree.leaves(states["flat"]["params"])
    )
    return {
        "n_devices": N_DEVICES,
        "mesh": "2x2x2 pod/data/model",
        "client_mode": cfg.client_mode,
        "n_clients": n_clients,
        "n_params": n_params,
        "sparsity": sparsity,
        "repeats": repeats,
        "per_leaf_step_ms": 1e3 * t_pl,
        "flat_step_ms": 1e3 * t_fl,
        "speedup": t_pl / t_fl,
        "per_leaf_compile_s": compile_pl,
        "flat_compile_s": compile_fl,
        "compile_speedup": compile_pl / compile_fl,
        "per_leaf_wire_ms": 1e3 * t_wire_pl,
        "device_pack_wire_ms": 1e3 * t_wire_pk,
        "wire_speedup": t_wire_pl / t_wire_pk,
        "wire_bytes": wire_bytes_pk,
        "wire_bytes_equal": wire_bytes_pl == wire_bytes_pk,
        "bits_per_client": flat.bits_per_client,
        "bits_equal": per_leaf.bits_per_client == flat.bits_per_client,
        "parity": bool(parity),
        "pack_parity": bool(pack_parity),
    }


def run(quick: bool = True) -> dict:
    """Spawn the 8-device child, collect and persist its measurement."""
    from benchmarks.common import save_json

    repeats = 5 if quick else 15
    env = dict(os.environ)
    # forced host devices only exist on the CPU backend — pin it so the
    # child's 8-device mesh builds on GPU/TPU dev boxes too
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "benchmarks.dist_flat",
            "--child",
            "--repeats",
            str(repeats),
        ],
        capture_output=True,
        text=True,
        timeout=1800,
        env=env,
        cwd=ROOT,
    )
    out = proc.stdout + proc.stderr
    if proc.returncode != 0:
        raise RuntimeError(f"dist_flat child failed:\n{out[-3000:]}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(MARK):
            payload = json.loads(line[len(MARK):])
    assert payload is not None, out[-3000:]
    assert payload["parity"], "flat and per-leaf params diverged"
    assert payload["pack_parity"], "device-pack and flat params diverged"
    assert payload["bits_equal"], "Eq. 1 bit accounting diverged"
    assert payload["wire_bytes_equal"], "wire byte totals diverged"
    assert payload["wire_speedup"] >= MIN_WIRE_SPEEDUP, (
        f"device-pack wire round speedup {payload['wire_speedup']:.2f} "
        f"< {MIN_WIRE_SPEEDUP}"
    )
    print(
        f"{payload['n_devices']} devices, {payload['n_clients']} clients, "
        f"{payload['n_params']} params, p={payload['sparsity']}"
    )
    print(
        f"per-leaf {payload['per_leaf_step_ms']:.1f} ms/step   "
        f"flat {payload['flat_step_ms']:.1f} ms/step   "
        f"x{payload['speedup']:.2f}  (parity={payload['parity']})"
    )
    print(
        f"compile: per-leaf {payload['per_leaf_compile_s']:.1f} s   "
        f"flat {payload['flat_compile_s']:.1f} s   "
        f"x{payload['compile_speedup']:.2f}"
    )
    print(
        f"wire round: host {payload['per_leaf_wire_ms']:.1f} ms   "
        f"device-pack {payload['device_pack_wire_ms']:.1f} ms   "
        f"x{payload['wire_speedup']:.2f}  "
        f"({payload['wire_bytes']} bytes, equal={payload['wire_bytes_equal']})"
    )
    path = save_json("dist_flat", payload)
    print(f"wrote {path}")
    return payload


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run (default size)")
    ap.add_argument("--full", action="store_true", help="more timing repeats")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--repeats", type=int, default=5)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.child:
        payload = _bench_child(args.repeats)
        print(MARK + json.dumps(payload))
        return
    run(quick=not args.full)


if __name__ == "__main__":
    main()
