"""Zoo-wide scale trajectory baseline (ISSUE 10).

Runs the :mod:`repro.scale` planner over EVERY config in the zoo and
commits one schema-versioned bits-per-step × step-time record per config
to ``experiments/scale/scale_zoo.json`` — the proof-point ledger the
``scale_zoo`` rule in ``benchmarks/check_regression.py`` gates.

All gated fields (analytic bit totals, leaf counts, memory budgets, the
bit-exact ``reconciles`` flag) are deterministic given the code: quick
mode only shortens the real tier's measured rounds, which affect nothing
but the ungated step-time numbers.

  PYTHONPATH=src python -m benchmarks.scale_zoo [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.paths import experiments_dir
from repro.scale.planner import plan_zoo

OUT_DIR = experiments_dir("scale")


def bench(quick: bool = True) -> list[dict]:
    return plan_zoo(rounds=3 if quick else 8)


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 measured rounds in the real tier (what CI runs)")
    args = ap.parse_args(argv)
    records = bench(quick=args.smoke)
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "scale_zoo.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")
    by_mode = {}
    for r in records:
        by_mode.setdefault(r["mode"], []).append(r["arch"])
    for mode in ("real", "dryrun", "analytic"):
        print(f"scale_zoo {mode}: {', '.join(by_mode.get(mode, []) or '-')}")
    bad = [r["arch"] for r in records if not r["reconciles"]]
    print(f"scale_zoo: {len(records)} records → {path} "
          f"({'all reconcile' if not bad else 'FAIL: ' + ', '.join(bad)})")
    return records


def run(quick: bool = True) -> list[dict]:
    """benchmarks.run harness hook."""
    return main(["--smoke"] if quick else [])


if __name__ == "__main__":
    main()
