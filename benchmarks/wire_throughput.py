"""Wire-format microbench: pack/unpack throughput per codec.

Measures the host-side serialization cost of `repro.core.wire` — bytes
produced, pack and unpack wall time, and effective MB/s over the dense
equivalent — for each registered codec on a mid-sized update.  This is the
number that bounds how fast a parameter server can turn around client
uploads (DESIGN.md §5).

  PYTHONPATH=src python -m benchmarks.wire_throughput          # quick
  PYTHONPATH=src python -m benchmarks.run --only wire_throughput
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import save_json
from repro.core import api
from repro.core.wire import wire_for

CODECS = ["sbc", "topk", "variance", "signsgd", "terngrad", "qsgd", "none"]


def bench_one(name: str, n: int, p: float, repeats: int) -> dict:
    delta = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.01}
    comp = api.make_compressor(name)
    state = comp.init_state(delta)
    ctree, dense, _ = comp.compress(delta, state, p)
    ctree = jax.tree.map(np.asarray, ctree)  # host-side, like a real server
    wire = wire_for(comp.resolve(delta), delta, p)

    blob = wire.pack(ctree)  # warm-up + correctness anchor
    rec = wire.unpack(blob)
    np.testing.assert_allclose(rec["w"], np.asarray(dense["w"], np.float32))

    t0 = time.perf_counter()
    for _ in range(repeats):
        wire.pack(ctree)
    t_pack = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        wire.unpack(blob)
    t_unpack = (time.perf_counter() - t0) / repeats

    dense_mb = 4.0 * n / 1e6
    return {
        "codec": name,
        "n": n,
        "p": p,
        "packed_bytes": len(blob),
        "measured_bits": wire.measured_bits(ctree),
        "compression": 32.0 * n / max(wire.measured_bits(ctree), 1),
        "pack_ms": 1e3 * t_pack,
        "unpack_ms": 1e3 * t_unpack,
        "pack_dense_mb_s": dense_mb / t_pack,
        "unpack_dense_mb_s": dense_mb / t_unpack,
    }


def run(quick: bool = True) -> None:
    n = 1_000_000 if quick else 25_000_000
    repeats = 5 if quick else 20
    rows = [bench_one(name, n, 0.01, repeats) for name in CODECS]
    print(
        f"{'codec':10s} {'packed':>10s} {'ratio':>8s} {'pack ms':>9s} "
        f"{'unpack ms':>9s} {'pack MB/s':>10s} {'unpack MB/s':>11s}"
    )
    for r in rows:
        print(
            f"{r['codec']:10s} {r['packed_bytes']:>9d}B "
            f"×{r['compression']:>6.0f} {r['pack_ms']:>8.2f} "
            f"{r['unpack_ms']:>8.2f} {r['pack_dense_mb_s']:>9.0f} "
            f"{r['unpack_dense_mb_s']:>10.0f}"
        )
    path = save_json("wire_throughput", rows)
    print(f"wrote {path}")


if __name__ == "__main__":
    run(quick=True)
