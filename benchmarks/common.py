"""Shared benchmark plumbing: tiny-but-learnable tasks, run helpers, output."""
from __future__ import annotations

import json
import os
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.core.api import make_compressor
from repro.data import client_batches, make_classification_task, make_lm_task
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.paths import experiments_dir
from repro.train import DSGDTrainer

OUT_DIR = experiments_dir("benchmarks")


def save_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def save_telemetry(name: str, telemetry, meta=None) -> dict:
    """Export a benchmark's repro.obs bundle as ``<name>.trace.json`` +
    ``<name>.metrics.jsonl`` next to its JSON record (CI uploads both and
    runs ``repro.obs.view --check`` over them).  No-op when disabled."""
    if not getattr(telemetry, "enabled", False):
        return {}
    from repro.obs import finish_run

    os.makedirs(OUT_DIR, exist_ok=True)
    return finish_run(
        telemetry,
        trace=os.path.join(OUT_DIR, f"{name}.trace.json"),
        metrics_out=os.path.join(OUT_DIR, f"{name}.metrics.jsonl"),
        meta=meta,
        print_summary=False,
    )


# ------------------------------------------------------- benchmark tasks
# The paper's 5 tasks map to synthetic stand-ins of 3 model families
# (offline container — DESIGN.md §8): conv / recurrent / transformer.


def bench_tasks(quick: bool = True):
    """[(tag, cfg, task, n_rounds, lr)] — one per model family."""
    out = []

    lenet = get_config("lenet5")
    t_img = make_classification_task(
        n_classes=10, img_size=28, channels=1, batch=32, noise=0.3
    )
    out.append(("lenet5@blobs", lenet, t_img, 40 if quick else 150, 1e-3))

    charlstm = get_config("charlstm")
    t_char = make_lm_task(vocab=98, batch=8, seq_len=64, temperature=0.5, seed=3)
    out.append(("charlstm@markov", charlstm, t_char, 40 if quick else 150, 0.5))

    tform = ModelConfig(
        name="transformer-s", family="decoder", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256, dtype=jnp.float32,
        local_opt="adam", base_lr=1e-3,
    )
    t_tf = make_lm_task(vocab=256, batch=8, seq_len=64, temperature=0.5, seed=5)
    out.append(("transformer@markov", tform, t_tf, 40 if quick else 150, 1e-3))
    return out


def run_training(
    cfg,
    task,
    *,
    compressor: str,
    n_rounds: int,
    delay: int,
    sparsity: float,
    lr: float,
    clients: int = 4,
    seed: int = 0,
):
    """One training run; returns history dict (loss curve, bits, rate)."""
    model = build_model(cfg)
    opt = get_optimizer(cfg.local_opt if cfg.local_opt != "momentum" else "momentum")
    with warnings.catch_warnings():
        # this harness benchmarks the trainer layer itself over custom
        # tasks; the legacy-surface warning targets end users
        warnings.simplefilter("ignore", DeprecationWarning)
        trainer = DSGDTrainer(
            model=model, compressor=make_compressor(compressor), optimizer=opt,
            n_clients=clients, lr=lambda it: lr,
        )
    batch_fn = client_batches(task, clients, delay)
    t0 = time.time()
    _, hist = trainer.fit(
        jax.random.PRNGKey(seed), batch_fn,
        n_rounds=max(1, n_rounds // delay), n_delay=delay, sparsity=sparsity,
    )
    hist["wall_s"] = time.time() - t0
    hist["iterations"] = [r * delay for r in hist["round"]]
    return hist


# paper §IV-B method grid: (name, compressor, delay, sparsity)
METHODS = [
    ("baseline", "none", 1, 1.0),
    ("grad_dropping", "topk", 1, 0.001),
    ("fedavg", "none", 10, 1.0),
    ("sbc1", "sbc", 1, 0.001),
    ("sbc2", "sbc", 10, 0.01),
    ("sbc3", "sbc", 100, 0.01),
]
