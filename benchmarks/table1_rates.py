"""Paper Table I — theoretical asymptotic compression rates per method,
validated against the EXACT Golomb bitstream on sampled sparsity patterns.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.core.bits import paper_table1, table1_row
from repro.core.golomb import encode_positions, expected_position_bits


def run(quick: bool = True) -> dict:
    n_params = 25_000_000  # ResNet50-scale, as in the paper's examples
    rows = []
    # the paper's ten methods, plus the variance-based selector (Tsuzuku
    # et al.) at Gradient-Dropping sparsity with Golomb positions — same
    # asymptotics as top-k, different survivors
    methods = paper_table1() + [
        table1_row("variance", sparsity=0.001, golomb=True)
    ]
    for mb in methods:
        rows.append({
            "method": mb.name,
            "temporal_sparsity": mb.temporal_sparsity,
            "gradient_sparsity": mb.gradient_sparsity,
            "value_bits": mb.value_bits,
            "position_bits": round(mb.position_bits, 2),
            "compression_rate": round(mb.compression_rate(n_params), 1),
        })

    # empirical Golomb validation at the paper's sparsity rates
    rng = np.random.default_rng(0)
    golomb_check = {}
    for p in (0.1, 0.01, 0.001):
        n = 300_000 if quick else 3_000_000
        idx = np.nonzero(rng.random(n) < p)[0]
        bits = encode_positions(idx, p)
        golomb_check[str(p)] = {
            "measured_bits_per_pos": round(bits.size / max(idx.size, 1), 3),
            "eq5_expected": round(expected_position_bits(p), 3),
            "naive_16bit_gain": round(16.0 / expected_position_bits(p), 2),
        }

    out = {"table1": rows, "golomb_validation": golomb_check}
    save_json("table1_rates", out)

    print(
        f"{'method':>20} {'f':>7} {'p':>7} {'vbits':>6} {'pbits':>6} {'rate':>10}"
    )
    for r in rows:
        print(
            f"{r['method']:>20} {r['temporal_sparsity']:>7.3f} "
            f"{r['gradient_sparsity']:>7.3f} {r['value_bits']:>6.1f} "
            f"{r['position_bits']:>6.2f} ×{r['compression_rate']:>9.1f}"
        )
    for p, g in golomb_check.items():
        print(
            f"golomb p={p}: measured {g['measured_bits_per_pos']} bits/pos "
            f"vs Eq.5 {g['eq5_expected']} (×{g['naive_16bit_gain']} vs 16-bit)"
        )
    return out


if __name__ == "__main__":
    run()
