"""Paper Fig. 4 — error at different TOTAL sparsity levels, split by
training stage (before/after the LR drop).

The paper's finding: early (high LR) temporal sparsity ≥ gradient
sparsity; after the LR decay the ordering flips.  We train the small
transformer in two phases (LR 0.05 → 0.005 at the midpoint) under
(a) purely temporal and (b) purely gradient sparsification at equal total
sparsity, and record the per-phase loss drop for each.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from benchmarks.common import bench_tasks, save_json
from repro.core.api import make_compressor
from repro.data import client_batches
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.train import DSGDTrainer


def run(quick: bool = True) -> dict:
    tag, cfg, task, n_rounds, lr0 = bench_tasks(quick)[2]
    iters = (n_rounds * 2) if quick else n_rounds * 4
    half = iters // 2
    model = build_model(cfg)
    totals = (1 / 8.0, 1 / 32.0) if quick else (1 / 8.0, 1 / 32.0, 1 / 128.0)

    out = {}
    for total in totals:
        for mode in ("temporal", "gradient"):
            delay = int(round(1 / total)) if mode == "temporal" else 1
            p = 1.0 if mode == "temporal" else total
            comp = "none" if p == 1.0 else "sbc"
            with warnings.catch_warnings():
                # stage-wise schedules need the trainer layer directly;
                # the legacy-surface warning targets end users
                warnings.simplefilter("ignore", DeprecationWarning)
                tr = DSGDTrainer(
                    model=model,
                    compressor=make_compressor(comp),
                    optimizer=get_optimizer(cfg.local_opt),
                    n_clients=4,
                    lr=lambda it: jnp.where(it < half, lr0, lr0 * 0.1),
                )
            state = tr.init(jax.random.PRNGKey(0))
            losses, it, r = [], 0, 0
            while it < iters:
                d = min(delay, iters - it)
                batch = client_batches(task, 4, d)(r)
                state, m = tr.round_step(state, batch, n_delay=d, sparsity=p)
                losses.append((it, float(m["loss"])))
                it += d
                r += 1
            phase1 = [l for i, l in losses if i < half]
            phase2 = [l for i, l in losses if i >= half]
            key = f"total={total:.4f}/{mode}"
            out[key] = {
                "loss_end_phase1": phase1[-1] if phase1 else None,
                "loss_end_phase2": phase2[-1] if phase2 else None,
                "delay": delay, "sparsity": p,
            }
            print(
                f"{key:>28}: phase1 {out[key]['loss_end_phase1']:.4f}  "
                f"phase2 {out[key]['loss_end_phase2']:.4f}"
            )
    save_json("fig4_stagewise", out)
    return out


if __name__ == "__main__":
    run()
