"""Federated round throughput: vmapped cohort runner vs the old Python loop.

Measures rounds/sec and bytes/round of the :mod:`repro.fed` orchestration
subsystem (one jitted vmap/scan step per cohort, DESIGN.md §9) against the
pre-subsystem baseline — the hand-rolled per-client Python loop that
``examples/federated_wire.py`` used to be: synchronous, full participation,
one jit dispatch per (client, local step), eager per-client compression,
dense server→client broadcast.

Both paths run the same model, task, policy, and wire format, so the
speedup is pure orchestration overhead.  The subsystem's ledger is also
reconciled against the analytic Eq. 1/Eq. 5 byte prediction every round
(ISSUE 2 acceptance: within Golomb rounding).

  PYTHONPATH=src python -m benchmarks.fed_round            # 16 clients
  PYTHONPATH=src python -m benchmarks.fed_round --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, save_telemetry
from repro.configs.base import ModelConfig
from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.core.wire import wire_for
from repro.data import make_lm_task
from repro.fed import ClientPool, ClientProfile, ParameterServer, RoundScheduler
from repro.models.model import build_model
from repro.optim import get_optimizer


def _setup(batch=4, seq_len=32):
    # the reduced config: small enough that orchestration (not the model's
    # FLOPs) is the measured quantity — at paper scale the compute term is
    # identical between the two paths anyway
    cfg = ModelConfig(
        name="fed-micro",
        family="decoder",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        dtype=jnp.float32,
    )
    model = build_model(cfg)
    task = make_lm_task(
        vocab=cfg.vocab_size, batch=batch, seq_len=seq_len, temperature=0.5
    )
    policy = CompressionPolicy(
        default=make_codec("sbc"),
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        name="sbc+dense-small",
    )
    return cfg, model, task, policy


def legacy_loop(model, task, policy, *, n_clients, delay, sparsity, rounds):
    """The old per-client Python orchestration loop, timed per round.

    (Loss reporting fixed relative to the original script: the mean over
    each client's delay window is recorded, not the last local step — and
    ``delay`` must be ≥ 1, the original crashed on an unbound ``loss`` at 0.)
    """
    if delay < 1:
        raise ValueError("delay must be >= 1")
    opt = get_optimizer("momentum")
    server_w = model.init(jax.random.PRNGKey(0))
    resolved = policy.resolve(server_w)
    wire = wire_for(resolved, server_w, sparsity)
    client_state = [resolved.init_state(server_w) for _ in range(n_clients)]
    client_opt = [opt.init(server_w) for _ in range(n_clients)]
    rates = resolved.rates(sparsity)
    step_fn = jax.jit(jax.value_and_grad(model.loss_fn))

    times, losses, up_bytes = [], [], 0
    for r in range(rounds):
        t0 = time.perf_counter()
        uploads = []
        for c in range(n_clients):
            w, ostate = server_w, client_opt[c]
            window = []
            for d in range(delay):
                loss, g = step_fn(w, task.sample(r * delay + d, c))
                w, ostate = opt.apply(
                    ostate, g, w, 0.05, jnp.asarray(r * delay + d)
                )
                window.append(float(loss))
            client_opt[c] = ostate
            losses.append(float(np.mean(window)))  # whole window, not last
            delta = jax.tree.map(lambda a, b: a - b, w, server_w)
            ctree, _, client_state[c] = resolved.compress(
                delta, client_state[c], rates
            )
            blob = wire.pack(ctree)
            uploads.append(blob)
            up_bytes += len(blob)
        mean_update = None
        for blob in uploads:
            update = wire.unpack(blob)
            mean_update = update if mean_update is None else jax.tree.map(
                np.add, mean_update, update
            )
        server_w = jax.tree.map(
            lambda p, u: p + jnp.asarray(u / n_clients, p.dtype),
            server_w, mean_update,
        )
        jax.block_until_ready(server_w)
        times.append(time.perf_counter() - t0)
    return times, losses, up_bytes / rounds


def fed_subsystem(
    model, task, policy, *, n_clients, delay, sparsity, rounds, telemetry=None
):
    """The same workload through ParameterServer/ClientPool/RoundScheduler."""
    from repro.obs import NULL_TELEMETRY

    tel = NULL_TELEMETRY if telemetry is None else telemetry
    server = ParameterServer(
        params=model.init(jax.random.PRNGKey(0)),
        up_policy=policy,
        down_sparsity=1.0,
    )
    pool = ClientPool(
        model=model,
        optimizer=get_optimizer("momentum"),
        policy=policy,
        task=task,
        n_clients=n_clients,
        lr=lambda it: 0.05,
        profiles=(ClientProfile(delay=delay, sparsity=sparsity),),
    )
    sched = RoundScheduler(server=server, pool=pool, cohort_size=n_clients)
    sched.channel.telemetry = tel
    server.telemetry = tel
    times, losses = [], []
    for r in range(rounds):
        t0 = time.perf_counter()
        with tel.span("round", round=r):
            m = sched.step(r)
            jax.block_until_ready(server.params)
        times.append(time.perf_counter() - t0)
        losses.append(m["loss"])
    sched.ledger.reconcile(rel=0.1)  # Eq. 1/Eq. 5 parity, every round
    tel.metrics.ingest_ledger(sched.ledger)
    t = sched.ledger.totals()
    return times, losses, t["up_bytes"] / rounds, t["down_bytes"] / rounds


def run(quick: bool = True, smoke: bool = False) -> dict:
    n_clients = 4 if smoke else 16
    delay = 2 if smoke else 3
    rounds = 2 if smoke else (5 if quick else 12)
    sparsity = 0.01
    _, model, task, policy = _setup()

    from repro.obs import make_telemetry

    telemetry = make_telemetry()
    t_new, loss_new, up_new, down_new = fed_subsystem(
        model, task, policy, n_clients=n_clients, delay=delay,
        sparsity=sparsity, rounds=rounds + 1, telemetry=telemetry,
    )
    t_old, loss_old, up_old = legacy_loop(
        model, task, policy, n_clients=n_clients, delay=delay,
        sparsity=sparsity, rounds=rounds + 1,
    )
    # drop round 0 (jit compile) from both timings; median resists the
    # occasional host-side hiccup on a shared machine
    rps_new = 1.0 / float(np.median(t_new[1:]))
    rps_old = 1.0 / float(np.median(t_old[1:]))
    out = {
        "n_clients": n_clients,
        "delay": delay,
        "sparsity": sparsity,
        "timed_rounds": rounds,
        "rounds_per_sec_legacy_loop": rps_old,
        "rounds_per_sec_vmapped": rps_new,
        "speedup": rps_new / rps_old,
        "up_bytes_per_round": up_new,
        "down_bytes_per_round": down_new,
        "up_bytes_per_round_legacy": up_old,
        "final_loss_vmapped": float(loss_new[-1]),
        "final_loss_legacy": float(loss_old[-1]),
        "ledger_reconciles": True,  # reconcile(rel=0.1) raised otherwise
    }
    print(
        f"clients={n_clients} delay={delay} p={sparsity} "
        f"({rounds} timed rounds)"
    )
    print(f"  legacy python loop : {rps_old:6.3f} rounds/s")
    print(
        f"  vmapped cohort     : {rps_new:6.3f} rounds/s  "
        f"(×{out['speedup']:.1f})"
    )
    print(
        f"  wire: up {up_new/1e3:.1f} kB/round, down {down_new/1e3:.1f} "
        f"kB/round — ledger reconciles with Eq. 1/Eq. 5 every round"
    )
    name = "fed_round_smoke" if smoke else "fed_round"
    path = save_json(name, out)
    print(f"wrote {path}")
    save_telemetry(
        name,
        telemetry,
        meta={"benchmark": name, "n_clients": n_clients, "rounds": rounds + 1},
    )
    if not smoke and out["speedup"] < 3.0:
        raise AssertionError(
            f"vmapped cohort runner only ×{out['speedup']:.2f} over the "
            "legacy loop (acceptance: ≥3× at 16 clients)"
        )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--full", action="store_true", help="more timed rounds")
    args = ap.parse_args(argv)
    run(quick=not args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
