"""§Roofline aggregation — reads experiments/dryrun/*.json (written by
repro.launch.dryrun) into the per-(arch × shape) roofline table for
EXPERIMENTS.md.  Does NOT launch compiles itself (run dryrun --all first).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_json

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments",
    "dryrun",
)


def load_records(mesh: str = "single") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True, mesh: str = "single") -> dict:
    recs = load_records(mesh)
    if not recs:
        print(
            "no dry-run records found — run "
            "`python -m repro.launch.dryrun --all` first"
        )
        return {}
    rows = []
    print(
        f"{'arch':>26} {'shape':>12} {'dom':>10} {'C(s)':>8} {'M(s)':>8} "
        f"{'X(s)':>8} {'useful':>7} {'temp GiB':>9}"
    )
    for r in recs:
        if r.get("status") == "skip":
            rows.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "status": "skip",
                    "reason": r["reason"],
                }
            )
            print(
                f"{r['arch']:>26} {r['shape']:>12} {'(skip)':>10}  "
                f"{r['reason'][:48]}"
            )
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"], "status": "error"})
            continue
        rf = r["roofline"]
        temp = (r["memory"].get("temp_bytes") or 0) / 2**30
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "status": "ok",
                "dominant": rf["dominant"],
                "compute_s": rf["compute_s"],
                "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "useful_ratio": rf["useful_ratio"],
                "temp_gib": temp,
            }
        )
        print(
            f"{r['arch']:>26} {r['shape']:>12} {rf['dominant']:>10} "
            f"{rf['compute_s']:>8.3f} {rf['memory_s']:>8.3f} "
            f"{rf['collective_s']:>8.3f} {rf['useful_ratio']:>7.2f} "
            f"{temp:>9.2f}"
        )
    out = {"mesh": mesh, "rows": rows}
    save_json(f"roofline_table_{mesh}", out)
    return out


if __name__ == "__main__":
    run()
