"""Benchmark harness entry point — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]

  table1_rates        Table I   theoretical rates + exact Golomb validation
  table2_accuracy     Table II  final loss + measured compression per method
  fig3_sparsity_grid  Fig. 3/9  temporal × gradient sparsity trade-off
  fig5_convergence    Fig. 5-8  loss vs iterations and vs transferred bits
  roofline_table      §Roofline aggregation of dry-run records (if present)
  wire_throughput     §Wire    pack/unpack microbench (DESIGN.md §5)
  pack_kernels        §11      device select→pack kernels vs host Golomb
                               encode+decode turnaround, byte-identity
                               asserted (docs/kernels.md)
  compress_e2e        §Flat    whole-pytree compress+pack: fast path vs
                               per-leaf baseline (DESIGN.md §10)
  fed_round           §Fed     vmapped cohort runner vs legacy loop (§9)
  dist_flat           §Dist    sharded flat exchange vs per-leaf shard_map
                               on 8 forced host devices (DESIGN.md §11)
  run_api_overhead    §12      Run/channel driver overhead vs the direct
                               trainer loop (<5% gate, DESIGN.md §12)
  broadcast_fanout    §13      delta-broadcast fan-out: bytes/subscriber/
                               round at 10k subscribers (DESIGN.md §13)

``--smoke`` runs only the fast, training-free benchmarks (what CI runs;
CI additionally smoke-runs ``fed_round --smoke`` and the fed launcher,
then gates the fresh JSONs against the committed baselines with
``benchmarks.check_regression``).
"""
from __future__ import annotations

import argparse
import sys
import time

SMOKE = (
    "table1_rates",
    "wire_throughput",
    "pack_kernels",
    "compress_e2e",
    "dist_flat",
    "run_api_overhead",
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs (slow)")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument(
        "--smoke", action="store_true", help="fast training-free subset (CI)"
    )
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    quick = not args.full

    from benchmarks import (
        broadcast_fanout,
        compress_e2e,
        dist_flat,
        fed_round,
        fig3_sparsity_grid,
        fig4_stagewise,
        fig5_convergence,
        pack_kernels,
        roofline_table,
        run_api_overhead,
        table1_rates,
        table2_accuracy,
        wire_throughput,
    )

    suite = {
        "table1_rates": table1_rates.run,
        "pack_kernels": pack_kernels.run,
        "table2_accuracy": table2_accuracy.run,
        "fig3_sparsity_grid": fig3_sparsity_grid.run,
        "fig4_stagewise": fig4_stagewise.run,
        "fig5_convergence": fig5_convergence.run,
        "roofline_table": roofline_table.run,
        "wire_throughput": wire_throughput.run,
        "compress_e2e": compress_e2e.run,
        "fed_round": fed_round.run,
        "dist_flat": dist_flat.run,
        "run_api_overhead": run_api_overhead.run,
        "broadcast_fanout": broadcast_fanout.run,
    }
    names = [args.only] if args.only else list(SMOKE) if args.smoke else list(suite)
    failures = []
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            suite[name](quick=quick)
            print(f"----- {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # pragma: no cover
            failures.append((name, repr(e)))
            import traceback

            traceback.print_exc()
    if failures:
        print("\nFAILURES:", failures)
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
