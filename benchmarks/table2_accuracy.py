"""Paper Table II — final loss + measured compression rate for every method
on each benchmark task (synthetic stand-ins, same model families).

The paper's claim validated here: SBC variants reach ≈ baseline loss in the
SAME number of forward-backward passes while uploading orders of magnitude
fewer bits (SBC1 ≈ ×2-3k, SBC2 ≈ ×3-4k, SBC3 ≈ ×25-37k).
"""
from __future__ import annotations

from benchmarks.common import METHODS, bench_tasks, run_training, save_json


def run(quick: bool = True) -> dict:
    results = {}
    for tag, cfg, task, n_rounds, lr in bench_tasks(quick):
        rows = {}
        for name, comp, delay, p in METHODS:
            if quick and name == "sbc3":
                delay = min(delay, 20)  # keep ≥2 rounds at quick scale
            hist = run_training(
                cfg,
                task,
                compressor=comp,
                n_rounds=n_rounds,
                delay=delay,
                sparsity=p,
                lr=lr,
            )
            rows[name] = {
                "final_loss": hist["loss"][-1],
                "first_loss": hist["loss"][0],
                "compression_rate": hist["compression_rate"],
                "upload_MB": hist["total_upload_bits"] / 8e6,
                "iterations": hist["iterations"][-1] + delay,
            }
            print(
                f"{tag:>22} {name:>14}: loss {rows[name]['final_loss']:.4f} "
                f"×{rows[name]['compression_rate']:.0f} "
                f"({rows[name]['upload_MB']:.3f} MB up)"
            )
        results[tag] = rows
    save_json("table2_accuracy", results)
    return results


if __name__ == "__main__":
    run()
