"""Elastic federation at 10k simulated clients on one host (DESIGN.md §14).

The device-store :class:`~repro.fed.ClientPool` keeps every client's
optimizer + error-feedback state as stacked device arrays — O(clients ·
model) resident bytes, which walls the simulation at a few hundred
clients.  The tiled cohort executor + spilled client store change the
memory shape, not the math:

  * ``--cohort-tile`` bounds the compiled step to a fixed member count, so
    device working-set is O(tile · model) regardless of population;
  * ``client_store="memmap"`` keeps the per-client pool rows in
    lazily-allocated on-disk ``.npy`` memmaps — never-sampled clients cost
    no resident pages (zero-initialized leaves are not even written), and
    a cohort's rows page in/out on gather/scatter.

This benchmark measures rounds/sec of a 10,000-client federation under a
64-member cohort with a 16-member tile, asserts the host's peak-RSS growth
stays a small fraction of the pool's LOGICAL state bytes (the device-store
cost), checks the memmap files stay sparse on disk, and re-proves the
executor is bit-transparent (tiled+spilled == untiled device, byte for
byte) before reporting.  The ledger reconciles measured-vs-analytic
(Eq. 1/Eq. 5) every round, wasted-byte column included.

  PYTHONPATH=src python -m benchmarks.fed_elastic          # 10k clients
  PYTHONPATH=src python -m benchmarks.fed_elastic --full   # more rounds
"""
from __future__ import annotations

import argparse
import os
import resource
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json
from repro.configs.base import ModelConfig
from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.data import make_lm_task
from repro.fed import ClientPool, ClientProfile, ParameterServer, RoundScheduler
from repro.models.model import build_model
from repro.optim import get_optimizer


def _setup():
    # sub-tiny decoder: the measured quantity is pool/executor overhead and
    # memory shape, not model FLOPs (the state-per-client ratio is what a
    # bigger model would only scale linearly)
    cfg = ModelConfig(
        name="elastic-micro",
        family="decoder",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        d_ff=64,
        vocab_size=64,
        dtype=jnp.float32,
    )
    model = build_model(cfg)
    task = make_lm_task(
        vocab=cfg.vocab_size, batch=2, seq_len=16, temperature=0.5
    )
    policy = CompressionPolicy(
        default=make_codec("sbc"),
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        name="sbc+dense-small",
    )
    return cfg, model, task, policy


def _federation(
    model,
    task,
    policy,
    *,
    n_clients,
    cohort,
    tile=None,
    store="device",
    store_dir=None,
):
    server = ParameterServer(
        params=model.init(jax.random.PRNGKey(0)),
        up_policy=policy,
        down_sparsity=0.1,
    )
    pool = ClientPool(
        model=model,
        optimizer=get_optimizer("momentum"),
        policy=policy,
        task=task,
        n_clients=n_clients,
        lr=lambda it: 0.05,
        profiles=(ClientProfile(delay=2, sparsity=0.05),),
        cohort_tile=tile,
        store=store,
        store_dir=store_dir,
    )
    return RoundScheduler(server=server, pool=pool, cohort_size=cohort)


def _state(sched):
    return jax.device_get(
        {
            "W": sched.server.params,
            "What": sched.server.estimate,
            "residual": sched.server.down_residual,
            "pool": sched.pool.export_state(),
        }
    )


def _bitwise(a, b) -> bool:
    la, pa = jax.tree_util.tree_flatten(a)
    lb, pb = jax.tree_util.tree_flatten(b)
    return pa == pb and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb)
    )


def _rss_bytes() -> int:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def _disk_bytes(directory: str) -> int:
    return sum(
        os.stat(os.path.join(dp, f)).st_blocks * 512
        for dp, _, files in os.walk(directory) for f in files
    )


def run(full: bool = False) -> dict:
    n_clients, cohort, tile = 10_000, 64, 16
    rounds = 8 if full else 3
    _, model, task, policy = _setup()
    n_params = sum(
        x.size for x in jax.tree.leaves(model.init(jax.random.PRNGKey(0)))
    )

    # ---- the headline run FIRST so its compile + paging dominate the RSS
    # delta we assert against (a later spike would hide under the high-water
    # mark of an earlier one)
    rss_start = _rss_bytes()
    with tempfile.TemporaryDirectory(prefix="fed-elastic-") as d:
        sched = _federation(
            model,
            task,
            policy,
            n_clients=n_clients,
            cohort=cohort,
            tile=tile,
            store="memmap",
            store_dir=d,
        )
        logical = sched.pool.state_nbytes()
        times = []
        rss_warm = rss_start
        for r in range(rounds + 1):  # round 0 pays the tile compile
            t0 = time.perf_counter()
            sched.step(r)
            jax.block_until_ready(sched.server.params)
            times.append(time.perf_counter() - t0)
            if r == 0:
                rss_warm = _rss_bytes()  # high-water after the compile spike
        sched.ledger.reconcile(rel=0.12)
        t = sched.ledger.totals()
        on_disk = _disk_bytes(d)
    rss_end = _rss_bytes()
    rss_total = max(0, rss_end - rss_start)  # includes the XLA compile arena
    rss_steady = max(0, rss_end - rss_warm)  # what the rounds themselves page in
    rps = 1.0 / float(np.median(times[1:]))
    # the whole point: a device store would pin `logical` bytes up front;
    # here the ENTIRE run — XLA compile arena included — grows the host's
    # high-water mark by less than that (steady-state growth is reported
    # but not gated: it is runner-noise territory at this scale)
    memory_bounded = rss_total < logical
    store_sparse = on_disk < logical

    # ---- bit-transparency at a size where the device reference still fits
    ref = _federation(model, task, policy, n_clients=48, cohort=16)
    alt = _federation(
        model, task, policy, n_clients=48, cohort=16, tile=6, store="memmap"
    )  # 16 = 6 + 6 + 4 (padded tile)
    for r in range(2):
        ref.step(r), alt.step(r)
    tile_parity = _bitwise(_state(ref), _state(alt))

    out = {
        "n_clients": n_clients,
        "cohort": cohort,
        "cohort_tile": tile,
        "timed_rounds": rounds,
        "n_params": int(n_params),
        "rounds_per_sec": rps,
        "pool_logical_bytes": int(logical),
        "peak_rss_delta_bytes": int(rss_total),
        "steady_rss_delta_bytes": int(rss_steady),
        "rss_over_logical": rss_total / logical,
        "store_disk_bytes": int(on_disk),
        "up_bytes_per_round": t["up_bytes"] / (rounds + 1),
        "down_bytes_per_round": t["down_bytes"] / (rounds + 1),
        "tile_parity": tile_parity,
        "memory_bounded": bool(memory_bounded),
        "store_sparse": bool(store_sparse),
        "ledger_reconciles": True,  # reconcile(rel=0.12) raised otherwise
    }
    print(
        f"clients={n_clients} cohort={cohort} tile={tile} "
        f"({rounds} timed rounds, memmap store)"
    )
    print(f"  throughput : {rps:6.2f} rounds/s")
    print(
        f"  memory     : pool logical {logical/1e6:.0f} MB, peak RSS delta "
        f"{rss_total/1e6:.0f} MB (×{out['rss_over_logical']:.2f}; "
        f"steady-state {rss_steady/1e6:.0f} MB), on disk {on_disk/1e6:.1f} MB"
    )
    print(
        f"  parity     : tiled+spilled == device untiled bitwise: {tile_parity}"
    )
    path = save_json("fed_elastic", out)
    print(f"wrote {path}")
    for flag in ("tile_parity", "memory_bounded", "store_sparse"):
        if not out[flag]:
            raise AssertionError(f"fed_elastic acceptance failed: {flag}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="more timed rounds")
    args = ap.parse_args(argv)
    run(full=args.full)


if __name__ == "__main__":
    main()
