"""Device-side wire packing microbench: pack+decode turnaround per path.

Times one wire TURNAROUND for a batch of sparse rows (one client upload):
selected positions → transport-grade Golomb bytes → positions again at
the server, two ways:

  host      the pre-§11 `repro.core.golomb` path behind ``Wire.pack``:
            one ``encode_positions_packed`` per row, then the server's
            sequential ``decode_positions`` scan per stream (the
            parameter-server hot path).
  device    the §11 kernels the flat exchange uses: one vmapped
            ``bits_from_positions`` + a single ``seg_packbits`` launch
            (exactly ``ShardedFlatParamSpace._pack_local``'s idiom),
            log-parallel ``golomb_decode_rows``, and transport bytes as
            a truncating copy (``golomb.packed_words_to_bytes``).

Both paths must produce byte-identical streams per row and decode back
to the original positions (asserted).  The row geometry matches the
embedding segment of the dist_flat bench model: n=32768, p=0.01 →
k=328, b*=6, 88 words/row.

  PYTHONPATH=src python -m benchmarks.pack_kernels          # quick
  PYTHONPATH=src python -m benchmarks.run --only pack_kernels
"""
from __future__ import annotations

import functools
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json
from repro.core import golomb
from repro.kernels.pack import (
    bits_from_positions,
    golomb_decode_rows,
    pack_bit_rows,
    row_words,
)

N = 32_768
P = 0.01


def run(quick: bool = True) -> dict:
    rows = 32 if quick else 128
    repeats = 7 if quick else 20
    k = max(1, round(N * P))
    bstar = golomb.golomb_bstar(P)
    w = row_words(N, k, bstar)
    scores = jax.random.normal(jax.random.PRNGKey(0), (rows, N))
    idx = jnp.sort(jnp.argsort(scores, axis=1)[:, -k:], axis=1).astype(
        jnp.int32
    )
    idx_np = np.asarray(idx)

    def _pack(pos):
        bits, nb = jax.vmap(
            functools.partial(bits_from_positions, bstar=bstar, cap32=32 * w)
        )(pos)
        return pack_bit_rows(bits), nb

    pack = jax.jit(_pack)
    dec = jax.jit(lambda ws: golomb_decode_rows(ws, k=k, bstar=bstar))

    def device_round() -> list:
        words, nbits = pack(idx)
        decoded = dec(words)
        jax.block_until_ready(decoded)
        w_np = np.asarray(jax.device_get(words))
        nb_np = np.asarray(jax.device_get(nbits))
        return [
            golomb.packed_words_to_bytes(w_np[r], int(nb_np[r]))
            for r in range(rows)
        ]

    def host_round() -> list:
        blobs = []
        for r in range(rows):
            blob, nb = golomb.encode_positions_packed(idx_np[r], P)
            bits = np.unpackbits(np.frombuffer(blob, np.uint8))[:nb]
            golomb.decode_positions(bits, P)
            blobs.append(blob)
        return blobs

    dev_blobs = device_round()  # compile + correctness anchor
    host_blobs = host_round()
    byte_identical = dev_blobs == host_blobs
    words, _ = pack(idx)
    decoded = np.asarray(dec(words))
    decode_roundtrip = bool(np.array_equal(decoded, idx_np))

    t_dev, t_host = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        device_round()
        t_dev.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        host_round()
        t_host.append(time.perf_counter() - t0)
    dev_ms = 1e3 * statistics.median(t_dev)
    host_ms = 1e3 * statistics.median(t_host)

    out = {
        "n": N,
        "rows": rows,
        "p": P,
        "k": k,
        "bstar": bstar,
        "words_per_row": w,
        "repeats": repeats,
        "bytes_total": sum(len(b) for b in dev_blobs),
        "byte_identical": bool(byte_identical),
        "decode_roundtrip": decode_roundtrip,
        "host_turnaround_ms": host_ms,
        "device_turnaround_ms": dev_ms,
        "speedup": host_ms / dev_ms,
    }
    assert out["byte_identical"], "device stream != host Wire.pack stream"
    assert out["decode_roundtrip"], "device decode lost positions"
    print(
        f"{rows} rows × n={N} (k={k}, b*={bstar}, {w} words/row): "
        f"host {host_ms:.2f} ms   device {dev_ms:.2f} ms   "
        f"x{out['speedup']:.2f}  ({out['bytes_total']} bytes, "
        f"identical={out['byte_identical']})"
    )
    path = save_json("pack_kernels", out)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    run()
