"""Benchmark regression gate: fresh --smoke output vs committed baselines.

CI copies the committed ``experiments/benchmarks/*.json`` aside, re-runs
the smoke benchmarks, then calls this module to compare fresh output
against the baseline with a tolerance band — a real gate instead of an
artifact upload.

Two kinds of checks per benchmark:

  * structural/correctness fields (parity flags, packed byte counts,
    analytic bit totals, shapes) must match the baseline (tiny relative
    tolerance for floats) — these are deterministic given the code, so
    any drift is a real behavior change and the baseline JSON must be
    regenerated deliberately;
  * speed ratios (fresh speedup ≥ baseline speedup / RATIO_BAND) use a
    wide band because shared CI runners are noisy; absolute ms values
    are never gated.

Only files present in BOTH directories and named in ``RULES`` are gated,
so adding a new benchmark is non-breaking until its baseline is
committed — but EVERY ``*.json`` the fresh directory holds must either
have a ``RULES`` entry or be named in ``UNGATED`` with a reason
(telemetry ``*.trace.json`` artifacts are validated by
``repro.obs.view --check`` instead).  A benchmark whose output nobody
gates is a benchmark that can rot silently; this module exits non-zero
on such strays.

  python -m benchmarks.check_regression --baseline /tmp/bench-baseline \
      --fresh experiments/benchmarks
"""

import argparse
import json
import os
import sys

RATIO_BAND = 3.0  # fresh speedup may degrade to 1/3 of baseline
REL_TOL = 0.02  # structural float fields (measured byte counts etc.)

# Committed/produced JSON stems deliberately outside the gate, with the
# reason on record.  Anything else without a RULES entry is an error.
UNGATED = {
    # full-suite artifact; the CI smoke sequence re-runs the --smoke
    # variant (gated as fed_round_smoke) and never regenerates this file
    "fed_round": "full-suite artifact, CI re-runs fed_round_smoke",
}

# per-benchmark field classes; list-valued JSONs match rows by the
# rule's "key" field ("arch" when unset).
# Only benchmarks the CI smoke sequence actually re-runs belong here —
# a stem CI never regenerates would be compared against its own copy.
RULES = {
    # Table I analytic rates + seeded Golomb validation: fully
    # deterministic in quick mode, so the whole payload is structural
    "table1_rates": {
        "exact": ("table1", "golomb_validation"),
    },
    # §5 wire microbench: codec geometry and stream sizes are
    # threefry-deterministic; throughput floats are runner noise
    "wire_throughput": {
        "key": "codec",
        "exact": ("codec", "n", "p"),
        "rel": ("packed_bytes", "measured_bits"),
    },
    # §11 device select→pack kernels: byte identity with the host
    # encoder and the decode round-trip are the acceptance claims
    "pack_kernels": {
        "exact": ("n", "rows", "k", "bstar", "words_per_row"),
        "true": ("byte_identical", "decode_roundtrip"),
        "rel": ("bytes_total",),
        "ratio_min": ("speedup",),
    },
    "compress_e2e": {
        "exact": ("arch", "n_params", "n_leaves", "packed_bytes"),
        "ratio_min": ("speedup_vs_per_leaf",),
    },
    "fed_round_smoke": {
        "exact": ("n_clients", "delay", "timed_rounds"),
        "true": ("ledger_reconciles",),
        "rel": (
            "up_bytes_per_round",
            "up_bytes_per_round_legacy",
            "down_bytes_per_round",
        ),
    },
    # §13 delta-broadcast fan-out: byte fields are threefry-deterministic,
    # so structural equality holds cross-machine; only throughput floats
    "broadcast_fanout": {
        "exact": (
            "n_subscribers",
            "timed_rounds",
            "horizon",
            "n_params",
            "full_resync_bytes",
        ),
        "true": (
            "catchup_beats_full_all_lags",
            "stack_bit_exact",
            "ledger_reconciles",
        ),
        "rel": ("bytes_per_subscriber_per_round",),
        "ratio_min": ("bytes_saving_vs_full_resync",),
    },
    # §14 elastic federation: structural fields are threefry-deterministic;
    # memory/parity booleans are the acceptance claims, throughput is noise
    "fed_elastic": {
        "exact": (
            "n_clients",
            "cohort",
            "cohort_tile",
            "timed_rounds",
            "n_params",
            "pool_logical_bytes",
        ),
        "true": (
            "tile_parity",
            "memory_bounded",
            "store_sparse",
            "ledger_reconciles",
        ),
        "rel": ("up_bytes_per_round", "down_bytes_per_round"),
    },
    # §14 chaos smoke: the CLI-level dropout/kill/resume contract — every
    # field that matters is a must-hold boolean
    "fed_chaos": {
        "exact": ("rounds", "clients", "cohort"),
        "true": (
            "resume_loss_bit_equal",
            "resume_ledger_equal",
            "loss_parity_vs_failure_free",
            "wasted_bytes_metered",
            "ledger_reconciles",
        ),
    },
    "dist_flat": {
        "exact": ("n_devices", "n_clients", "n_params"),
        "true": ("parity", "pack_parity", "bits_equal", "wire_bytes_equal"),
        "rel": ("bits_per_client", "wire_bytes"),
        "ratio_min": ("speedup", "compile_speedup", "wire_speedup"),
    },
    # §15 zoo-wide scale trajectories: every bit/memory field is pure
    # deterministic arithmetic over shapes + policy rates, and
    # `reconciles` carries the bit-exact ledger cross-check on the real
    # tier; step times (real.step_ms_*, roofline_est) are never gated
    "scale_zoo": {
        "key": "arch",
        "exact": (
            "schema",
            "arch",
            "family",
            "mode",
            "params",
            "active_params",
            "compressor",
            "sparsity",
            "clients",
            "n_leaves",
            "mesh",
            "framing_bytes",
            "param_bytes",
            "residual_bytes",
            "optimizer_bytes",
        ),
        "true": ("reconciles",),
        "rel": (
            "up_bits_per_step",
            "up_bits_f32_ledger",
            "dense_bits",
            "compression_rate",
        ),
    },
    # §12 channel/Run driver overhead vs the direct trainer loop: the
    # <5% bound is computed by the benchmark itself (interleaved medians),
    # so the gate only needs the boolean + stable structural fields
    "run_api_overhead": {
        "exact": (
            "preset",
            "n_clients",
            "timed_rounds",
            "bound",
            "telemetry_bound",
        ),
        "true": ("overhead_within_bound", "telemetry_disabled_within_bound"),
    },
}


def _check_record(name: str, rule: dict, base: dict, fresh: dict) -> list:
    errs = []
    for f in rule.get("exact", ()):
        b, x = base.get(f), fresh.get(f)
        if x != b:
            errs.append(f"{name}.{f}: {x!r} != baseline {b!r}")
    for f in rule.get("true", ()):
        if fresh.get(f) is not True:
            errs.append(f"{name}.{f}: expected true, got {fresh.get(f)!r}")
    for f in rule.get("rel", ()):
        b, x = base.get(f), fresh.get(f)
        if b is None or x is None:
            errs.append(f"{name}.{f}: missing (base={b!r}, fresh={x!r})")
        elif abs(x - b) > REL_TOL * max(abs(b), 1e-12):
            errs.append(f"{name}.{f}: {x} drifted >2% from baseline {b}")
    for f in rule.get("ratio_min", ()):
        b, x = base.get(f), fresh.get(f)
        if b is None or x is None:
            errs.append(f"{name}.{f}: missing (base={b!r}, fresh={x!r})")
        elif x < b / RATIO_BAND:
            floor = b / RATIO_BAND
            errs.append(f"{name}.{f}: {x:.3f} regressed below {floor:.3f}")
    return errs


def compare_file(stem: str, base_path: str, fresh_path: str) -> list:
    rule = RULES[stem]
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    if type(base) is not type(fresh):
        tb, tf = type(base).__name__, type(fresh).__name__
        return [f"{stem}: JSON shape changed (baseline {tb}, fresh {tf})"]
    if isinstance(base, dict):
        return _check_record(stem, rule, base, fresh)
    errs = []
    key = rule.get("key", "arch")
    fresh_by = {r.get(key): r for r in fresh}
    for row in base:
        name = row.get(key)
        got = fresh_by.get(name)
        if got is None:
            errs.append(f"{stem}: {key} {name!r} missing from fresh output")
            continue
        errs.extend(_check_record(f"{stem}[{name}]", rule, row, got))
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, help="dir of committed JSONs")
    ap.add_argument("--fresh", required=True, help="dir of fresh smoke JSONs")
    args = ap.parse_args(argv)

    checked, errs = [], []
    for stem in sorted(RULES):
        base_path = os.path.join(args.baseline, stem + ".json")
        fresh_path = os.path.join(args.fresh, stem + ".json")
        has_base = os.path.exists(base_path)
        has_fresh = os.path.exists(fresh_path)
        if has_fresh and not has_base:
            # loud, not fatal: a fresh benchmark without a committed
            # baseline is not gated yet — do not let it pass silently
            print(f"[skip] {stem} (no committed baseline)")
            continue
        if has_base and not has_fresh:
            print(f"[skip] {stem} (baseline committed but no fresh output)")
            continue
        if not has_base:
            continue
        file_errs = compare_file(stem, base_path, fresh_path)
        checked.append(stem)
        status = "FAIL" if file_errs else "ok"
        print(f"[{status:4s}] {stem}")
        errs.extend(file_errs)
    # every fresh JSON must be gated or exempt on record — a benchmark
    # output nobody compares is a gate that rots silently
    for fname in sorted(os.listdir(args.fresh)):
        if not fname.endswith(".json") or fname.endswith(".trace.json"):
            continue
        stem = fname[: -len(".json")]
        if stem in RULES:
            continue
        if stem in UNGATED:
            print(f"[skip] {stem} (ungated: {UNGATED[stem]})")
            continue
        errs.append(
            f"{stem}: fresh JSON has no RULES entry — add one (or list it "
            f"in UNGATED with a reason)"
        )
    if not checked:
        print("no gated benchmarks found in both directories", file=sys.stderr)
        return 1
    for e in errs:
        print(f"  regression: {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
