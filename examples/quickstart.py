"""Quickstart: compress one weight-update with SBC, end to end.

Walks the full paper pipeline on a single tensor:
  residual add → top-p% sparsify → binarize to ±μ (Alg. 2)
  → Golomb-encode positions (Alg. 3) → wire message → decode (Alg. 4).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golomb
from repro.core.api import get_compressor
from repro.core.golomb import decode_sbc_message, encode_sbc_message, message_bits

# a fake "weight update" for one layer
rng = jax.random.PRNGKey(0)
delta = {"layer0/w": jax.random.normal(rng, (512, 256)) * 0.01}

# --- compress with error feedback (paper Alg. 1 lines 10-12)
sbc = get_compressor("sbc")
state = sbc.init_state(delta)
p = 0.01
compressed, dense_update, state = sbc.compress(delta, state, p)

leaf = compressed["layer0/w"]
n = delta["layer0/w"].size
print(f"tensor: {n} params, sparsity p={p}")
print(f"survivors: {leaf.idx.shape[0]} positions, ONE value μ={float(leaf.mean):.6f}")
print(f"analytic wire size: {float(leaf.nbits):.0f} bits "
      f"(dense 32-bit: {32*n} bits → ×{32*n/float(leaf.nbits):.0f})")

# --- exact wire format: Golomb-coded positions + one 32-bit mean (Alg. 3)
msg = encode_sbc_message(np.asarray(leaf.idx), float(leaf.mean), p)
print(f"exact bitstream: {message_bits(msg)} bits "
      f"({msg['nbits_positions']/leaf.idx.shape[0]:.2f} bits/position; "
      f"Eq. 5 predicts {golomb.expected_position_bits(p):.2f})")

# --- receiver side (Alg. 4)
reconstructed = decode_sbc_message(msg, n).reshape(512, 256)
np.testing.assert_allclose(reconstructed, np.asarray(dense_update["layer0/w"]),
                           rtol=1e-6)
print("receiver reconstruction matches ✓")

# --- the residual keeps what was not sent (Eq. 2)
res = state.residual["layer0/w"]
np.testing.assert_allclose(np.asarray(res + dense_update["layer0/w"]),
                           np.asarray(delta["layer0/w"]), rtol=1e-5)
print("residual + transmitted == full update ✓ (no information lost)")
