"""Quickstart: the staged codec pipeline, end to end on one weight update.

Walks the full paper pipeline through the PR's API layers:
  codec stages (Selector → Quantizer → Encoder)  …  Alg. 2
  per-leaf policy (dense biases, SBC matrices)   …  DGC-style rules
  error feedback through compress()              …  Alg. 1 l.10-12 / Eq. 2
  packed wire bytes + measured-vs-analytic bits  …  Alg. 3/4, Eq. 1/5

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import golomb
from repro.core.api import CompressionPolicy, PolicyRule, make_compressor
from repro.core.codec import make_codec
from repro.core.wire import wire_for

# a fake "weight update": one matrix + one bias vector
rng = jax.random.PRNGKey(0)
delta = {
    "layer0/w": jax.random.normal(rng, (512, 256)) * 0.01,
    "layer0/bias": jax.random.normal(jax.random.PRNGKey(1), (256,)) * 0.01,
}

# --- 1. a codec is a composition of three registered stages
sbc = make_compressor("sbc")  # shim → topk_signed|binarize|golomb
print(f"SBC as a staged codec: {sbc.codec.spec}")

# --- 2. per-leaf policy: the bias rides dense, the matrix gets SBC
policy = CompressionPolicy(
    default=make_codec("sbc"),
    rules=(PolicyRule(r"bias$", codec="dense32"),),
    name="quickstart",
)
resolved = policy.resolve(delta)
print(resolved.describe())

# --- 3. compress with error feedback (paper Alg. 1 lines 10-12)
p = 0.01
state = resolved.init_state(delta)
rates = resolved.rates(p)
compressed, dense_update, state = resolved.compress(delta, state, rates)

leaf = compressed["layer0/w"]
n = delta["layer0/w"].size
print(f"\nmatrix: {n} params, sparsity p={p}")
print(f"survivors: {leaf.idx.shape[0]} positions, ONE value μ={float(leaf.mean):.6f}")
print(f"analytic wire size: {float(leaf.nbits):.0f} bits "
      f"(dense 32-bit: {32*n} bits → ×{32*n/float(leaf.nbits):.0f})")

# --- 4. exact wire format: pack the whole update to one byte buffer
wire = wire_for(resolved, delta, p)
blob = wire.pack(compressed)
measured = wire.measured_bits(compressed)
print(f"\npacked buffer: {len(blob)} bytes; measured payload {measured} bits "
      f"vs analytic {float(resolved.total_bits(compressed)):.0f} bits "
      f"(Eq. 5 predicts {golomb.expected_position_bits(p):.2f} bits/position)")

# --- 5. receiver side (Alg. 4): bytes → identical dense update
reconstructed = wire.unpack(blob)
for key in delta:
    np.testing.assert_allclose(reconstructed[key],
                               np.asarray(dense_update[key]), rtol=1e-6)
print("receiver reconstruction matches ✓")

# --- 6. the residual keeps what was not sent (Eq. 2); the dense bias
#        leaf transmits in full, so its residual is exactly zero
res = state.residual["layer0/w"]
np.testing.assert_allclose(np.asarray(res + dense_update["layer0/w"]),
                           np.asarray(delta["layer0/w"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(state.residual["layer0/bias"]), 0.0,
                           atol=1e-7)
print("residual + transmitted == full update ✓ (no information lost)")
