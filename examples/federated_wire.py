"""Federated-learning wire simulation — the paper's privacy-preserving
setting (§I): clients exchange ONLY Golomb-coded SBC messages (real
bitstreams, not in-process arrays) with a parameter server.

Each round:
  1. every client trains locally (communication delay n) and SBC-compresses
     its weight-update,
  2. the update crosses the "network" as packed bytes
     (positions: Golomb bitstream, Alg. 3; one float32 mean per tensor),
  3. the server decodes (Alg. 4), averages, and broadcasts new weights.

Run:  PYTHONPATH=src python examples/federated_wire.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.api import get_compressor
from repro.core.golomb import decode_sbc_message, encode_sbc_message, message_bits
from repro.data import make_lm_task
from repro.models.model import build_model
from repro.optim import get_optimizer

N_CLIENTS, DELAY, SPARSITY, ROUNDS = 4, 5, 0.01, 10

cfg = ModelConfig(name="fed-tiny", family="decoder", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                  dtype=jnp.float32)
model = build_model(cfg)
task = make_lm_task(vocab=256, batch=8, seq_len=64, temperature=0.5)
opt = get_optimizer("momentum")
sbc = get_compressor("sbc")

rng = jax.random.PRNGKey(0)
server_w = model.init(rng)
client_state = [sbc.init_state(server_w) for _ in range(N_CLIENTS)]
client_opt = [opt.init(server_w) for _ in range(N_CLIENTS)]

step_fn = jax.jit(jax.value_and_grad(model.loss_fn))

n_params = sum(x.size for x in jax.tree.leaves(server_w))
total_wire_bytes = 0
for r in range(ROUNDS):
    uploads, losses = [], []
    for c in range(N_CLIENTS):
        # --- client: delay-n local training from the server weights
        w, ostate = server_w, client_opt[c]
        for d in range(DELAY):
            loss, g = step_fn(w, task.sample(r * DELAY + d, c))
            w, ostate = opt.apply(ostate, g, w, 0.05, jnp.asarray(r * DELAY + d))
        client_opt[c] = ostate
        losses.append(float(loss))
        delta = jax.tree.map(lambda a, b: a - b, w, server_w)

        # --- compress + encode to actual bytes
        ctree, dense, client_state[c] = sbc.compress(delta, client_state[c], SPARSITY)
        msgs = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                ctree, is_leaf=lambda x: hasattr(x, "idx"))[0]:
            key = "/".join(k.key for k in path)
            msgs[key] = encode_sbc_message(np.asarray(leaf.idx),
                                           float(leaf.mean), SPARSITY)
        uploads.append(msgs)
        total_wire_bytes += sum(message_bits(m) for m in msgs.values()) / 8

    # --- server: decode every client's bitstream, average, apply
    flat_w, treedef = jax.tree_util.tree_flatten_with_path(server_w)
    new_leaves = []
    for path, leaf in flat_w:
        key = "/".join(k.key for k in path)
        acc = np.zeros(leaf.size, np.float32)
        for c in range(N_CLIENTS):
            acc += decode_sbc_message(uploads[c][key], leaf.size)
        new_leaves.append(leaf + (acc / N_CLIENTS).reshape(leaf.shape))
    server_w = jax.tree_util.tree_unflatten(
        jax.tree.structure(server_w), new_leaves)

    dense_bytes = 4 * n_params * N_CLIENTS * (r + 1) * DELAY
    print(f"round {r+1:2d}: mean client loss {np.mean(losses):.4f}  "
          f"wire so far {total_wire_bytes/1e3:.1f} kB "
          f"(dense DSGD would be {dense_bytes/1e6:.1f} MB → "
          f"×{dense_bytes/max(total_wire_bytes,1):.0f})")

print("\nfederated run complete — every byte that crossed the 'network' was a "
      "real Golomb bitstream")
