"""Federated-learning wire demo — the paper's privacy-preserving setting
(§I): clients exchange ONLY packed SBW1 byte buffers with a parameter
server, in BOTH directions.

This is now a thin wrapper over the federated orchestration subsystem
(:mod:`repro.fed`, DESIGN.md §9):

  * :class:`ParameterServer` unpacks every client's framed buffer (Alg. 4),
    aggregates, keeps a server-side error-feedback residual, and compresses
    the downstream broadcast through the same per-leaf policy machinery,
  * :class:`ClientPool` runs each sampled cohort as ONE vmapped/lax.scan
    step (no per-client Python loop) with per-client residuals + RNG,
  * :class:`RoundScheduler` drives the rounds and meters every byte both
    ways against the analytic Eq. 1/Eq. 5 prediction.

Richer knobs (async staleness, non-IID shards, heterogeneous client
profiles, weighted aggregation) live in the CLI:

  PYTHONPATH=src python -m repro.launch.fed --help

Run:  PYTHONPATH=src python examples/federated_wire.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.data import make_lm_task
from repro.fed import ClientPool, ClientProfile, ParameterServer, RoundScheduler
from repro.models.model import build_model
from repro.optim import get_optimizer

N_CLIENTS, COHORT, DELAY, SPARSITY, DOWN_SPARSITY, ROUNDS = 4, 4, 5, 0.01, 0.05, 10

cfg = ModelConfig(name="fed-tiny", family="decoder", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                  dtype=jnp.float32)
model = build_model(cfg)
task = make_lm_task(vocab=256, batch=8, seq_len=64, temperature=0.5)

policy = CompressionPolicy(
    default=make_codec("sbc"),
    rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
    name="sbc+dense-small",
)

server = ParameterServer(
    params=model.init(jax.random.PRNGKey(0)),
    up_policy=policy,            # shared wire contract with the clients
    down_sparsity=DOWN_SPARSITY,  # the broadcast is compressed too
)
pool = ClientPool(
    model=model, optimizer=get_optimizer("momentum"), policy=policy,
    task=task, n_clients=N_CLIENTS, lr=lambda it: 0.05,
    profiles=(ClientProfile(delay=DELAY, sparsity=SPARSITY),),
)
sched = RoundScheduler(server=server, pool=pool, cohort_size=COHORT)

print(pool.resolved(server.params).describe())
hist = sched.run(ROUNDS, log_every=1)
sched.ledger.reconcile(rel=0.1)

n_params = sum(x.size for x in jax.tree.leaves(server.params))
t = sched.ledger.totals()
dense_up = 4 * n_params * N_CLIENTS * ROUNDS * DELAY  # dense DSGD, per step
print(
    f"\nwire totals: up {t['up_bytes']/1e3:.1f} kB, down {t['down_bytes']/1e3:.1f} kB "
    f"(dense DSGD upload would be {dense_up/1e6:.1f} MB → "
    f"×{dense_up/max(t['up_bytes'],1):.0f})"
)
print("every byte that crossed the 'network' was a real packed SBW1 buffer, "
      "both directions, and the ledger reconciles with Eq. 1/Eq. 5")
