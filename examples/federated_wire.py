"""Federated-learning wire simulation — the paper's privacy-preserving
setting (§I): clients exchange ONLY packed byte buffers (real bitstreams,
not in-process arrays) with a parameter server.

Built on the staged codec pipeline (DESIGN.md):

  * a per-leaf :class:`CompressionPolicy` sends biases/norm parameters
    dense (they are tiny and sparsification hurts them most — the DGC
    recipe) and SBC-compresses every matrix at 1%,
  * each client's update is serialized by :class:`repro.core.wire.Wire`
    into ONE framed buffer — Golomb position bitstreams (Alg. 3), one
    float32 mean per sparse tensor, raw float32 for the dense leaves,
  * the server holds the same Wire contract (model config + policy are
    shared), unpacks every client's buffer (Alg. 4), averages, and
    broadcasts new weights.

Run:  PYTHONPATH=src python examples/federated_wire.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.wire import wire_for
from repro.data import make_lm_task
from repro.models.model import build_model
from repro.optim import get_optimizer

N_CLIENTS, DELAY, SPARSITY, ROUNDS = 4, 5, 0.01, 10

cfg = ModelConfig(name="fed-tiny", family="decoder", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                  dtype=jnp.float32)
model = build_model(cfg)
task = make_lm_task(vocab=256, batch=8, seq_len=64, temperature=0.5)
opt = get_optimizer("momentum")

policy = CompressionPolicy(
    default=make_codec("sbc"),
    rules=(PolicyRule(r"(^|/)(bias|scale|norm[^/]*)(/|$)", codec="dense32"),),
    name="sbc+dense-small",
)

rng = jax.random.PRNGKey(0)
server_w = model.init(rng)
resolved = policy.resolve(server_w)
wire = wire_for(resolved, server_w, SPARSITY)  # both ends share this contract
client_state = [resolved.init_state(server_w) for _ in range(N_CLIENTS)]
client_opt = [opt.init(server_w) for _ in range(N_CLIENTS)]
rates = resolved.rates(SPARSITY)

print(resolved.describe())
step_fn = jax.jit(jax.value_and_grad(model.loss_fn))

n_params = sum(x.size for x in jax.tree.leaves(server_w))
total_wire_bytes = 0
for r in range(ROUNDS):
    uploads, losses = [], []
    for c in range(N_CLIENTS):
        # --- client: delay-n local training from the server weights
        w, ostate = server_w, client_opt[c]
        for d in range(DELAY):
            loss, g = step_fn(w, task.sample(r * DELAY + d, c))
            w, ostate = opt.apply(ostate, g, w, 0.05, jnp.asarray(r * DELAY + d))
        client_opt[c] = ostate
        losses.append(float(loss))
        delta = jax.tree.map(lambda a, b: a - b, w, server_w)

        # --- compress (per-leaf policy + error feedback) + pack to bytes
        ctree, dense, client_state[c] = resolved.compress(
            delta, client_state[c], rates
        )
        blob = wire.pack(ctree)
        uploads.append(blob)
        total_wire_bytes += len(blob)

    # --- server: decode every client's byte buffer, average, apply
    mean_update = None
    for blob in uploads:
        update = wire.unpack(blob)  # dense numpy pytree
        if mean_update is None:
            mean_update = update
        else:
            mean_update = jax.tree.map(np.add, mean_update, update)
    server_w = jax.tree.map(
        lambda p, u: p + jnp.asarray(u / N_CLIENTS, p.dtype),
        server_w, mean_update,
    )

    dense_bytes = 4 * n_params * N_CLIENTS * (r + 1) * DELAY
    print(f"round {r+1:2d}: mean client loss {np.mean(losses):.4f}  "
          f"wire so far {total_wire_bytes/1e3:.1f} kB "
          f"(dense DSGD would be {dense_bytes/1e6:.1f} MB → "
          f"×{dense_bytes/max(total_wire_bytes,1):.0f})")

print("\nfederated run complete — every byte that crossed the 'network' was a "
      "real packed SBW1 buffer")
