"""End-to-end driver: train a ~100M-parameter decoder LM with DSGD + SBC
for a few hundred communication rounds (deliverable (b)).

Four clients jointly train on a synthetic Markov corpus; SBC(2)-style
settings (delay 10, p = 1%).  Prints the loss curve and the measured
upload compression vs 32-bit dense DSGD.

Run:  PYTHONPATH=src python examples/train_lm_100m.py [--rounds 30]
(the default 30 rounds ≈ 300 forward-backward passes keeps CPU wall-time
reasonable; pass --rounds 300 for the full few-hundred-round run)
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--delay", type=int, default=10)
    ap.add_argument("--sparsity", type=float, default=0.01)
    args = ap.parse_args()

    train_main([
        "--preset", "lm-100m",
        "--compressor", "sbc",
        "--clients", "4",
        "--delay", str(args.delay),
        "--sparsity", str(args.sparsity),
        "--rounds", str(args.rounds),
        "--batch", "4",
        "--seq-len", "128",
        "--log-every", "5",
        "--history", "experiments/benchmarks/lm100m_history.json",
    ])
