"""§III demo: the temporal-vs-gradient sparsity trade-off and the adaptive
controller (the paper's §V "future work", implemented as a beyond-paper
feature in core/sparsity.py).

Trains the same model three ways under an IDENTICAL total-sparsity budget:
  A. purely temporal   (delay 16, dense updates)    — Federated Averaging
  B. purely gradient   (delay 1, p = 1/16)          — Gradient Dropping line
  C. adaptive schedule (temporal early, gradient after the LR drop)

Run:  PYTHONPATH=src python examples/sparsity_tradeoff.py
"""
import warnings

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import make_compressor
from repro.core.sparsity import adaptive_total_budget
from repro.data import client_batches, make_lm_task
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.train import DSGDTrainer

BUDGET = 1.0 / 16.0  # total sparsity = (1/delay)·p
ITERS = 64

cfg = ModelConfig(name="tradeoff", family="decoder", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                  dtype=jnp.float32)
model = build_model(cfg)
task = make_lm_task(vocab=256, batch=8, seq_len=64, temperature=0.5)


def run(tag, schedule):
    # dense rounds (p = 1) exchange full updates (FedAvg semantics);
    # sparse rounds go through SBC — both share the same model state.
    # Per-round adaptive schedules need the trainer layer directly (a
    # RunSpec pins one static schedule), so the legacy warning is muted.
    def mk(name):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return DSGDTrainer(
                model=model, compressor=make_compressor(name),
                optimizer=get_optimizer("momentum"), n_clients=4,
                lr=lambda it: 0.05,
            )
    tr_sbc, tr_dense = mk("sbc"), mk("none")
    state = tr_sbc.init(jax.random.PRNGKey(0))
    total_bits, it, r, last = 0.0, 0, 0, 0.0
    while it < ITERS:
        delay, p = schedule(r)
        delay = min(delay, ITERS - it)
        tr = tr_dense if p >= 1.0 else tr_sbc
        bf = client_batches(task, 4, delay)
        state, m = tr.round_step(state, bf(r), n_delay=delay, sparsity=p)
        total_bits += float(m["bits_per_client"])
        it += delay
        r += 1
        last = float(m["loss"])
    print(f"{tag:>22}: loss {last:.4f} after {ITERS} iters, "
          f"{total_bits:.3e} bits/client")
    return last


run("temporal (fedavg-ish)", lambda r: (16, 1.0))
run("gradient (GD-ish)", lambda r: (1, BUDGET))
sched = adaptive_total_budget(BUDGET, lr_schedule=lambda r: 0.05 if r < 2 else 0.005,
                              base_lr=0.05, max_delay=16)
run("adaptive (§V)", sched)
