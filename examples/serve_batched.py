"""Batched serving demo: prefill a batch of prompts on a reduced gemma3
(5:1 local:global attention) and a reduced jamba (mamba hybrid), then
decode with the one-token serve_step the decode_32k / long_500k dry-run
shapes exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax

from repro.configs.base import get_config, reduced
from repro.models.model import build_model
from repro.serve import ServeEngine

for arch in ("gemma3-1b", "jamba-v0.1-52b"):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    engine = ServeEngine(model)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    B, PROMPT, NEW = 4, 48, 24
    batch = {"tokens": jax.random.randint(rng, (B, PROMPT), 0, cfg.vocab_size)}

    t0 = time.time()
    out = engine.generate(params, batch, max_new_tokens=NEW, temperature=0.8,
                          rng=rng)
    dt = time.time() - t0
    print(f"{arch:>16} (reduced): {B} prompts × {NEW} new tokens "
          f"in {dt:.2f}s — cache kinds: "
          f"{sorted(set(cfg.layer_kinds))}")
    print(f"{'':>16}  sample: {out[0, :12].tolist()}")
